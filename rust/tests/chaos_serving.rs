//! Chaos suite: the serving engine under deterministic fault injection
//! (`rilq::engine::ChaosScorer` — seeded schedules of `Err` returns,
//! delays, and panics at forward-call ordinals).
//!
//! Three invariants, proved under every injected failure mode:
//!
//! 1. **every `Pending` resolves** — Ok or Err, never a hang;
//! 2. **the KV arena drains** — `blocks_in_use() == 0` once the traffic
//!    is answered, faults, failovers, and cross-request prefix-cache
//!    pins included (every abort path releases shared blocks exactly
//!    once);
//! 3. **retried work is bitwise-identical to a fault-free run** — a
//!    score that survived a retry, or a generation that failed over to a
//!    peer replica mid-decode, returns exactly the tokens/logps of the
//!    clean scorer.
//!
//! PR 10 extends the suite with overload robustness: seeded bursty
//! multi-tenant traces flood the admission-control path while faults
//! fire, shedding must hit the low-priority class only, the slow-replica
//! watchdog retires dragging replicas, and the rejection counters
//! partition the Err answers exactly.

use std::sync::Arc;
use std::time::Duration;

use rilq::engine::{
    generate_trace, Arrivals, BoundedPareto, ChaosScorer, Dispatch, Engine, EngineConfig, Fault,
    HealthView, OverloadKind, Overloaded, Priority, Request, RoundRobin, SamplingParams,
    SubmitOptions, TenantClass, TraceConfig,
};
use rilq::eval::{greedy_decode, BackendScorer, Scorer};
use rilq::model::backend::BackendKind;
use rilq::model::{ModelDims, StudentWeights, TeacherParams};
use rilq::quant::{by_name, CalibCtx};
use rilq::tensor::Rng;

fn dims() -> ModelDims {
    ModelDims {
        name: "chaos".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 48,
        seq: 16,
        batch: 4,
        group_size: 8,
    }
}

fn scorer_for(seed: u64, kind: BackendKind) -> Arc<BackendScorer> {
    let d = dims();
    let mut rng = Rng::seed(seed);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    Arc::new(BackendScorer::new(&d, &teacher, &student, None, kind).unwrap())
}

fn packed_scorer(seed: u64) -> Arc<BackendScorer> {
    scorer_for(seed, BackendKind::Packed)
}

/// Route every submission to one fixed replica (lets the panic test aim
/// all generations at the faulty replica).
struct Sticky(usize);

impl Dispatch for Sticky {
    fn route(&self, _req: &Request, _health: &HealthView) -> usize {
        self.0
    }
}

/// Seeded `Err` + delay faults on a single replica: every request
/// resolves, retried scores and generations are bitwise-identical to the
/// fault-free scorer, the retry counter moved, and the arena drains.
#[test]
fn every_pending_resolves_under_seeded_err_and_delay_faults() {
    let clean = packed_scorer(71);
    let d = clean.dims().clone();
    // call 1 always faults (the retry path deterministically fires) plus
    // six seeded faults across the first 16 calls
    let chaos =
        ChaosScorer::new(clean.clone()).with_fault(1, Fault::Err).seeded(0x5eed, 6, 16, false);
    let engine = Engine::start_shared(
        Arc::new(chaos),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            // generous budget: with only 7 scheduled faults, no request
            // can exhaust it — everything must resolve Ok
            max_retries: 10,
            // single replica: transient injected errors must not retire
            // the only scorer
            unhealthy_after: usize::MAX,
            retry_backoff: Duration::from_millis(1),
            ..EngineConfig::default()
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();
    let mut rng = Rng::seed(72);
    let seqs: Vec<Vec<u32>> = (0..8)
        .map(|_| (0..8).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let prompts: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..4).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let max_new = 6usize;
    let want_scores = clean.score_all(&seqs).unwrap();
    let want_gens: Vec<_> =
        prompts.iter().map(|p| greedy_decode(clean.as_ref(), p, max_new).unwrap()).collect();

    let pscores: Vec<_> = seqs.iter().map(|s| client.score(s.clone()).unwrap()).collect();
    let pgens: Vec<_> = prompts
        .iter()
        .map(|p| client.generate(p.clone(), SamplingParams::greedy(max_new)).unwrap())
        .collect();
    for (k, (p, want)) in pscores.into_iter().zip(&want_scores).enumerate() {
        // invariant 1: resolves (wait_timeout, so a hang fails fast);
        // invariant 3: the answer that survived retries is bitwise clean
        let got = p
            .wait_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("score {k} did not resolve Ok: {e}"));
        assert_eq!(got.len(), want.len(), "score {k} wrong length");
        for (a, b) in got.iter().zip(want) {
            assert!(
                a.to_bits() == b.to_bits(),
                "score {k} diverged from the fault-free run ({a} vs {b})"
            );
        }
    }
    for (k, (g, (toks, lps))) in pgens.into_iter().zip(&want_gens).enumerate() {
        let got = g
            .wait_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("generation {k} did not resolve Ok: {e}"));
        assert_eq!(&got.tokens, toks, "generation {k} tokens diverged across retries");
        assert_eq!(got.logps.len(), lps.len());
        for (a, b) in got.logps.iter().zip(lps) {
            assert!(
                a.to_bits() == b.to_bits(),
                "generation {k}: logp not bitwise identical ({a} vs {b})"
            );
        }
    }
    drop(client);
    let summary = engine.shutdown();
    assert!(summary.retries >= 1.0, "the scheduled call-1 fault was never retried");
    assert_eq!(summary.errors, 0.0, "a fault leaked through the retry budget");
    // invariant 2: nothing holds arena blocks after the drain
    assert_eq!(arena.blocks_in_use(), 0, "faulted traffic leaked arena blocks");
}

/// An injected panic mid-decode: the supervision guard catches it, the
/// replica is marked unhealthy (sticky), and the in-flight generation
/// fails over to the healthy peer — resuming via the replay path,
/// bitwise-identical to a run that never crashed.
#[test]
fn panic_fault_fails_over_generation_bitwise_to_healthy_replica() {
    let clean = packed_scorer(73);
    let d = clean.dims().clone();
    let mut rng = Rng::seed(74);
    // prompt 8 with prefill_chunk 4: call 1 = first prefill chunk,
    // call 2 = prefill completion (first token sampled), call 3 = first
    // decode step — the panic fires with sampled tokens in flight, so
    // the failover must carry replay state, not just the prompt
    let prompt: Vec<u32> = (0..8).map(|_| rng.below(d.vocab) as u32).collect();
    let max_new = 6usize;
    let (want_toks, want_lps) = greedy_decode(clean.as_ref(), &prompt, max_new).unwrap();

    let chaotic = Arc::new(ChaosScorer::new(clean.clone()).with_fault(3, Fault::Panic));
    let replicas: Vec<Arc<dyn Scorer + Send + Sync>> = vec![chaotic.clone(), clean.clone()];
    let engine = Engine::start_sharded(
        replicas,
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            ..EngineConfig::default()
        },
        // everything targets the replica that will crash
        Arc::new(Sticky(0)),
    );
    let arenas: Vec<_> = engine.arenas().to_vec();
    let health = engine.health();
    let client = engine.client();

    let got = client
        .generate(prompt.clone(), SamplingParams::greedy(max_new))
        .unwrap()
        .wait_timeout(Duration::from_secs(60))
        .expect("the failed-over generation never resolved");
    assert_eq!(got.tokens, want_toks, "failover diverged from the crash-free decode");
    for (a, b) in got.logps.iter().zip(&want_lps) {
        assert!(
            a.to_bits() == b.to_bits(),
            "failover logp not bitwise identical ({a} vs {b})"
        );
    }
    assert!(chaotic.injected() >= 1, "the scheduled panic never fired");
    assert!(!health.is_healthy(0), "the panicked replica must be marked unhealthy");
    assert_eq!(health.healthy_count(), 1);

    // the fleet keeps serving on the surviving replica (routing skips
    // the dead hint)
    let seq: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();
    let want = clean.score_all(std::slice::from_ref(&seq)).unwrap();
    let after = client
        .score(seq)
        .unwrap()
        .wait_timeout(Duration::from_secs(60))
        .expect("post-crash traffic starved");
    assert_eq!(after.len(), want[0].len());

    drop(client);
    let summary = engine.shutdown();
    assert!(summary.retries >= 1.0, "the failover never counted as a retry");
    for (i, a) in arenas.iter().enumerate() {
        assert_eq!(a.blocks_in_use(), 0, "replica {i} leaked arena blocks through the crash");
    }
}

/// Injected latency faults push a deadlined generation past its budget:
/// it resolves with the deadline `Err` (shed from the queue or aborted
/// mid-decode, wherever the expiry lands) and its blocks drain.
#[test]
fn delay_faults_trip_deadlines() {
    let clean = packed_scorer(75);
    let d = clean.dims().clone();
    let mut chaos = ChaosScorer::new(clean);
    // every one of the first 6 calls stalls well past the deadline
    for call in 1..=6 {
        chaos = chaos.with_fault(call, Fault::Delay(Duration::from_millis(50)));
    }
    let engine = Engine::start_shared(
        Arc::new(chaos),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            ..EngineConfig::default()
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();
    let mut rng = Rng::seed(76);
    let prompt: Vec<u32> = (0..4).map(|_| rng.below(d.vocab) as u32).collect();
    let err = client
        .generate_with(
            prompt,
            SamplingParams::greedy(10),
            &SubmitOptions::with_deadline(Duration::from_millis(60)),
        )
        .unwrap()
        .wait_timeout(Duration::from_secs(60))
        .expect_err("a generation stalled past its deadline must resolve Err");
    assert!(format!("{err}").contains("deadline"), "{err}");
    drop(client);
    let summary = engine.shutdown();
    assert!(
        summary.deadline_aborts + summary.shed >= 1.0,
        "the expiry was counted neither as a shed nor as a mid-decode abort"
    );
    assert_eq!(arena.blocks_in_use(), 0, "the deadline abort leaked arena blocks");
}

/// Prefix cache under chaos (the PR-8 × prefix-index interaction):
/// shared-prompt generations attach cached KV blocks while seeded `Err`
/// faults force preempt/replay, one request is cancelled mid-flight and
/// one arrives with an expired deadline — every surviving answer is
/// bitwise identical to the fault-free decode, and after the drain the
/// arena holds zero blocks and the index zero pins: every abort, retry,
/// and cancellation path decremented its prefix refcounts exactly once.
#[test]
fn prefix_cache_survives_faults_cancellation_and_deadlines() {
    let clean = packed_scorer(79);
    let d = clean.dims().clone();
    let chaos =
        ChaosScorer::new(clean.clone()).with_fault(1, Fault::Err).seeded(0xca5e, 6, 20, false);
    let engine = Engine::start_shared(
        Arc::new(chaos),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            // 8-token shared system prompt = 2 whole blocks of 4
            kv_block: 4,
            max_retries: 12,
            unhealthy_after: usize::MAX,
            retry_backoff: Duration::from_millis(1),
            ..EngineConfig::default()
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();
    let mut rng = Rng::seed(80);
    let sys: Vec<u32> = (0..8).map(|_| rng.below(d.vocab) as u32).collect();
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|_| {
            let mut p = sys.clone();
            p.extend((0..2).map(|_| rng.below(d.vocab) as u32));
            p
        })
        .collect();
    let max_new = 4usize;
    let want: Vec<_> =
        prompts.iter().map(|p| greedy_decode(clean.as_ref(), p, max_new).unwrap()).collect();

    // warm the index: the first shared-prompt generation publishes the
    // system prompt's committed blocks, retrying through call 1's
    // scheduled fault on the way
    let warm = client
        .generate(prompts[0].clone(), SamplingParams::greedy(max_new))
        .unwrap()
        .wait_timeout(Duration::from_secs(60))
        .expect("warm generation did not resolve under faults");
    assert_eq!(&warm.tokens, &want[0].0, "warm decode diverged under faults");

    // mixed abandonment wave, all sharing the cached prefix: one served,
    // one cancelled mid-flight, one dead on arrival (expired deadline)
    let live = client.generate(prompts[1].clone(), SamplingParams::greedy(max_new)).unwrap();
    let doomed = client.generate(prompts[2].clone(), SamplingParams::greedy(max_new)).unwrap();
    let expired = client
        .generate_with(
            prompts[3].clone(),
            SamplingParams::greedy(max_new),
            &SubmitOptions::with_deadline(Duration::from_millis(0)),
        )
        .unwrap();
    doomed.cancel();
    let got = live
        .wait_timeout(Duration::from_secs(60))
        .expect("shared-prefix generation did not resolve under faults");
    assert_eq!(&got.tokens, &want[1].0, "cached-prefix decode diverged under faults");
    assert_eq!(got.logps.len(), want[1].1.len());
    for (a, b) in got.logps.iter().zip(&want[1].1) {
        assert!(
            a.to_bits() == b.to_bits(),
            "cached-prefix logp not bitwise identical ({a} vs {b})"
        );
    }
    let err = doomed
        .wait_timeout(Duration::from_secs(60))
        .expect_err("a cancelled generation must resolve Err");
    assert!(format!("{err}").contains("cancelled"), "{err}");
    let err = expired
        .wait_timeout(Duration::from_secs(60))
        .expect_err("an expired generation must resolve Err");
    assert!(format!("{err}").contains("deadline"), "{err}");

    drop(client);
    let summary = engine.shutdown();
    assert!(summary.retries >= 1.0, "the scheduled call-1 fault was never retried");
    assert!(summary.prefix_hits >= 1.0, "no shared prompt ever hit the index");
    assert!(
        summary.prefix_tokens_saved >= 8.0,
        "the cached system prompt was re-prefilled: {} tokens saved",
        summary.prefix_tokens_saved
    );
    assert!(summary.cancelled >= 1.0, "the cancellation was never counted");
    assert!(
        summary.shed + summary.deadline_aborts >= 1.0,
        "the expired request was neither shed nor aborted"
    );
    // the load-bearing invariant: faults, cancellation, and deadline
    // aborts all released their shared-block holds exactly once
    assert_eq!(summary.kv_blocks_pinned, 0.0, "index pins survived the drain");
    assert_eq!(arena.blocks_in_use(), 0, "faulted/cancelled traffic leaked arena blocks");
}

/// The harness itself is deterministic: the same seed yields the same
/// schedule, and driving two identically-seeded `ChaosScorer`s through
/// the same call sequence injects at the same ordinals with bitwise-
/// identical surviving answers — a failing chaos run always reproduces.
#[test]
fn seeded_chaos_runs_reproduce_bitwise() {
    let mut rng = Rng::seed(77);
    let d = dims();
    let seqs: Vec<Vec<u32>> = (0..6)
        .map(|_| (0..8).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let run = |seed: u64| {
        let chaos = ChaosScorer::new(packed_scorer(78)).seeded(seed, 3, 6, false);
        let schedule = chaos.schedule();
        let outs: Vec<Result<Vec<Vec<f32>>, String>> = seqs
            .iter()
            .map(|s| {
                chaos.score_batch(std::slice::from_ref(s)).map_err(|e| format!("{e}"))
            })
            .collect();
        (schedule, outs, chaos.injected())
    };
    let (sched_a, outs_a, injected_a) = run(0xabcd);
    let (sched_b, outs_b, injected_b) = run(0xabcd);
    assert_eq!(sched_a, sched_b, "same seed, different schedule");
    assert!(injected_a >= 1, "the seeded schedule never fired in 6 calls");
    assert_eq!(injected_a, injected_b);
    for (k, (a, b)) in outs_a.iter().zip(&outs_b).enumerate() {
        match (a, b) {
            (Ok(x), Ok(y)) => {
                let same = x.len() == y.len()
                    && x.iter().zip(y).all(|(r, s)| {
                        r.len() == s.len()
                            && r.iter().zip(s).all(|(p, q)| p.to_bits() == q.to_bits())
                    });
                assert!(same, "call {k}: surviving answers diverged between identical runs");
            }
            (Err(x), Err(y)) => assert_eq!(x, y, "call {k}: fault messages diverged"),
            _ => panic!("call {k}: one run faulted where the other succeeded"),
        }
    }
    // a different seed actually changes the schedule (the harness is not
    // degenerate)
    let other = ChaosScorer::new(packed_scorer(78)).seeded(0x1234, 3, 6, false);
    assert_ne!(sched_a, other.schedule());

    // RoundRobin is irrelevant to this test but keeps the import honest
    // across cfg combinations
    let _ = RoundRobin::new();
}

/// PR-10 tentpole under faults, across every native backend: a seeded
/// bursty two-tenant trace floods an engine running admission control
/// while `ChaosScorer` injects Err faults. The trace regenerates
/// bit-for-bit, every `Pending` resolves, every surviving answer is
/// bitwise-identical to the fault-free decode, and the arena drains.
#[test]
fn bursty_trace_under_faults_resolves_drains_and_matches() {
    for kind in [BackendKind::Dense, BackendKind::Packed, BackendKind::Merged] {
        let clean = scorer_for(81, kind);
        let d = clean.dims().clone();
        let cfg = TraceConfig {
            seed: 0xb125,
            duration_secs: 1.5,
            arrivals: Arrivals::OnOff {
                on_rate: 30.0,
                off_rate: 2.0,
                on_secs: 0.5,
                off_secs: 0.5,
            },
            tenants: vec![
                TenantClass { name: "paid".into(), priority: Priority::High, weight: 0.25 },
                TenantClass { name: "free".into(), priority: Priority::Low, weight: 0.75 },
            ],
            // prompt.hi + gen.hi stays inside the 16-token model window
            prompt: BoundedPareto { alpha: 1.3, lo: 2, hi: 8 },
            gen: BoundedPareto { alpha: 1.5, lo: 1, hi: 4 },
            vocab: d.vocab,
        };
        let trace = generate_trace(&cfg);
        assert_eq!(trace, generate_trace(&cfg), "[{kind}] trace must regenerate bit-for-bit");
        assert!(trace.len() >= 8, "[{kind}] degenerate trace ({} events)", trace.len());
        let want: Vec<_> = trace
            .iter()
            .map(|ev| greedy_decode(clean.as_ref(), &ev.prompt, ev.max_new.max(1)).unwrap())
            .collect();

        let chaos = ChaosScorer::new(clean.clone())
            .with_fault(1, Fault::Err)
            .seeded(0xfa57, 6, 24, false);
        let engine = Engine::start_shared(
            Arc::new(chaos),
            EngineConfig {
                max_batch: 4,
                queue_capacity: 16,
                max_active: 2,
                prefill_chunk: 4,
                shed_watermark: 0.75,
                max_retries: 12,
                unhealthy_after: usize::MAX,
                retry_backoff: Duration::from_millis(1),
                ..EngineConfig::default()
            },
        );
        let arena = engine.arenas()[0].clone();
        let client = engine.client();
        // the whole burst floods in without pacing — worst case for the
        // admission path
        let pendings: Vec<_> = trace
            .iter()
            .map(|ev| {
                client
                    .generate_with(
                        ev.prompt.clone(),
                        SamplingParams::greedy(ev.max_new.max(1)),
                        &SubmitOptions::default()
                            .priority(ev.priority)
                            .tenant(ev.tenant.clone()),
                    )
                    .unwrap()
            })
            .collect();
        let mut okd = 0usize;
        for (k, (p, (toks, lps))) in pendings.into_iter().zip(&want).enumerate() {
            // invariant 1: every Pending resolves — Ok or typed Err,
            // never a hang (the timeout error contains "within")
            match p.wait_timeout(Duration::from_secs(60)) {
                Ok(got) => {
                    okd += 1;
                    // invariant 3: survivors are bitwise-identical to
                    // the fault-free decode
                    assert_eq!(&got.tokens, toks, "[{kind}] event {k} tokens diverged");
                    for (a, b) in got.logps.iter().zip(lps) {
                        assert!(
                            a.to_bits() == b.to_bits(),
                            "[{kind}] event {k}: logp not bitwise identical"
                        );
                    }
                }
                Err(e) => {
                    assert!(
                        !format!("{e}").contains("within"),
                        "[{kind}] event {k} never resolved: {e}"
                    );
                }
            }
        }
        assert!(okd > 0, "[{kind}] the burst answered nothing at all");
        drop(client);
        let summary = engine.shutdown();
        assert!(summary.retries >= 1.0, "[{kind}] the scheduled call-1 fault never retried");
        // invariant 2: the arena drains
        assert_eq!(arena.blocks_in_use(), 0, "[{kind}] bursty faulted traffic leaked blocks");
    }
}

/// PR-10 tentpole: a low-priority flood over the watermark must not
/// touch paid traffic. Every high-priority request completes, low
/// rejections answer the typed `Overloaded` (QueueFull, Low) and the
/// `serve.overload_sheds` counter mirrors them exactly, sustained
/// backlog brownout fires, and the arena drains.
#[test]
fn high_priority_goodput_survives_a_low_priority_flood() {
    let clean = packed_scorer(83);
    let d = clean.dims().clone();
    // slow every forward slightly so the flood genuinely backs up the
    // queue (the tiny model would otherwise drain as fast as we submit)
    let mut chaos = ChaosScorer::new(clean.clone());
    for call in 1..=200 {
        chaos = chaos.with_fault(call, Fault::Delay(Duration::from_millis(2)));
    }
    let engine = Engine::start_shared(
        Arc::new(chaos),
        EngineConfig {
            max_batch: 4,
            // watermark at ceil(0.75 × 16) = 12 — above the 5 paid
            // requests, so a paid arrival over the watermark always
            // finds a free-tier victim to displace and is never shed
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            shed_watermark: 0.75,
            brownout_backlog: 6,
            brownout_after: 1,
            brownout_max_new: 1,
            unhealthy_after: usize::MAX,
            ..EngineConfig::default()
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();
    let mut rng = Rng::seed(84);
    let mut prompt =
        |n: usize| -> Vec<u32> { (0..n).map(|_| rng.below(d.vocab) as u32).collect() };
    // 40 free/Low generations flood in first, then 5 paid/High arrive
    // into the saturated queue
    let lows: Vec<_> = (0..40)
        .map(|_| {
            client
                .generate_with(
                    prompt(4),
                    SamplingParams::greedy(6),
                    &SubmitOptions::default().priority(Priority::Low).tenant("free"),
                )
                .unwrap()
        })
        .collect();
    let highs: Vec<_> = (0..5)
        .map(|_| {
            client
                .generate_with(
                    prompt(4),
                    SamplingParams::greedy(4),
                    &SubmitOptions::default().priority(Priority::High).tenant("paid"),
                )
                .unwrap()
        })
        .collect();
    for (k, h) in highs.into_iter().enumerate() {
        h.wait_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("high-priority request {k} was not protected: {e}"));
    }
    let mut low_ok = 0usize;
    let mut low_shed = 0usize;
    for (k, l) in lows.into_iter().enumerate() {
        match l.wait_timeout(Duration::from_secs(60)) {
            Ok(_) => low_ok += 1,
            Err(e) => {
                let o = e
                    .downcast_ref::<Overloaded>()
                    .unwrap_or_else(|| panic!("low {k} failed with a non-shed error: {e}"));
                assert_eq!(o.kind, OverloadKind::QueueFull, "low {k}: wrong rejection kind");
                assert_eq!(o.priority, Priority::Low);
                low_shed += 1;
            }
        }
    }
    drop(client);
    let summary = engine.shutdown();
    assert!(low_shed >= 1, "a 40-deep flood over a 12-entry watermark never shed");
    assert!(low_ok >= 1, "shedding degraded into rejecting everything");
    assert_eq!(
        summary.overload_sheds,
        low_shed as f64,
        "the shed counter must mirror the typed answers exactly"
    );
    assert_eq!(summary.overload_sheds_high, 0.0, "a high-priority request was shed");
    assert!(summary.goodput_requests >= 5.0, "paid goodput lost: {}", summary.goodput_requests);
    assert!(
        summary.ttft_high_p99_secs.is_some(),
        "the high-priority TTFT series was never observed"
    );
    assert!(summary.brownouts >= 1.0, "sustained backlog never browned out the free tier");
    assert_eq!(arena.blocks_in_use(), 0, "the flood leaked arena blocks");
}

/// Satellite: the slow-replica watchdog. Persistent injected `Delay`
/// faults push one replica's forwards over `slow_forward_threshold`;
/// after `slow_streak_limit` consecutive slow forwards the watchdog
/// marks it sticky-unhealthy and routing moves to the peer. Everything
/// resolves, `serve.slow_forwards` moved, and the arenas drain.
#[test]
fn slow_replica_watchdog_trips_sticky_and_traffic_fails_over() {
    let clean = packed_scorer(85);
    let d = clean.dims().clone();
    let mut slow = ChaosScorer::new(clean.clone());
    for call in 1..=8 {
        slow = slow.with_fault(call, Fault::Delay(Duration::from_millis(5)));
    }
    let replicas: Vec<Arc<dyn Scorer + Send + Sync>> = vec![Arc::new(slow), clean.clone()];
    let engine = Engine::start_sharded(
        replicas,
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            slow_forward_threshold: Duration::from_millis(1),
            slow_streak_limit: 3,
            ..EngineConfig::default()
        },
        // everything aims at the replica that will drag
        Arc::new(Sticky(0)),
    );
    let arenas: Vec<_> = engine.arenas().to_vec();
    let health = engine.health();
    let client = engine.client();
    let mut rng = Rng::seed(86);
    // sequential scores: each is one forward on replica 0, so the 5ms
    // delays accumulate an unbroken slow streak
    for k in 0..4 {
        let s: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();
        let want = clean.score_all(std::slice::from_ref(&s)).unwrap();
        let got = client
            .score(s)
            .unwrap()
            .wait_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("slow-replica score {k} did not resolve: {e}"));
        assert_eq!(got.len(), want[0].len(), "score {k} wrong length");
    }
    assert!(!health.is_healthy(0), "three 5ms forwards over a 1ms threshold must trip");
    assert_eq!(health.healthy_count(), 1);
    // the fleet keeps serving — routing skips the sticky-unhealthy hint
    let s: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();
    client
        .score(s)
        .unwrap()
        .wait_timeout(Duration::from_secs(60))
        .expect("post-watchdog traffic starved");
    drop(client);
    let summary = engine.shutdown();
    assert!(
        summary.slow_forwards >= 3.0,
        "slow forwards undercounted: {}",
        summary.slow_forwards
    );
    for (i, a) in arenas.iter().enumerate() {
        assert_eq!(a.blocks_in_use(), 0, "replica {i} leaked blocks through the watchdog trip");
    }
}

/// Satellite regression: rejection accounting is a partition. A request
/// both past its deadline AND over the watermark counts once — deadline
/// wins — so the rejection counters sum to exactly the number of Err
/// answers, never more.
#[test]
fn rejection_counters_partition_the_err_answers() {
    let clean = packed_scorer(87);
    let d = clean.dims().clone();
    let mut chaos = ChaosScorer::new(clean);
    for call in 1..=60 {
        chaos = chaos.with_fault(call, Fault::Delay(Duration::from_millis(3)));
    }
    let engine = Engine::start_shared(
        Arc::new(chaos),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 8, // watermark at 6
            max_active: 2,
            prefill_chunk: 4,
            shed_watermark: 0.75,
            unhealthy_after: usize::MAX,
            ..EngineConfig::default()
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();
    let mut rng = Rng::seed(88);
    let mut pendings = Vec::new();
    for k in 0..24 {
        let p: Vec<u32> = (0..4).map(|_| rng.below(d.vocab) as u32).collect();
        // every third request arrives already expired — over the
        // watermark it is also sheddable, and must count once, in
        // `shed`, not `overload_sheds`
        let opts = if k % 3 == 0 {
            SubmitOptions::with_deadline(Duration::ZERO)
        } else {
            SubmitOptions::default().priority(Priority::Low)
        };
        pendings.push(client.generate_with(p, SamplingParams::greedy(4), &opts).unwrap());
    }
    let mut n_ok = 0usize;
    let mut n_err = 0usize;
    for (k, p) in pendings.into_iter().enumerate() {
        match p.wait_timeout(Duration::from_secs(60)) {
            Ok(_) => n_ok += 1,
            Err(e) => {
                assert!(!format!("{e}").contains("within"), "request {k} hung: {e}");
                n_err += 1;
            }
        }
    }
    drop(client);
    let summary = engine.shutdown();
    assert!(n_err >= 1, "no request was rejected — the partition was never exercised");
    let partitioned = summary.shed
        + summary.deadline_aborts
        + summary.cancelled
        + summary.rate_limited
        + summary.overload_sheds
        + summary.errors;
    assert_eq!(
        partitioned, n_err as f64,
        "rejections double- or under-counted ({n_ok} ok / {n_err} err)"
    );
    assert_eq!(arena.blocks_in_use(), 0, "rejected traffic leaked arena blocks");
}
