//! Integration tests for the request-lifecycle engine
//! (`rilq::engine`): ragged scoring mixes are answered correctly with no
//! PAD-dummy forwards, coalescing happens under load, bad requests don't
//! poison their batchmates, shutdown drains, decode scheduling (chunked
//! prefill + lockstep steps) matches single-stream greedy decode, score
//! traffic is admitted *between* decode iterations (no head-of-line
//! blocking behind full decode slots), `wait_timeout` fails fast on a
//! wedged worker, and the deprecated `ServeClient` shims still serve.
//!
//! Fault-tolerance lifecycle (deadlines, cancellation, failover) is
//! covered here too: a dropped or cancelled `Pending` aborts its
//! generation and frees its arena blocks, expired work is shed or
//! aborted at step boundaries, shutdown under a mixed burst resolves
//! every `Pending` with zero blocks leaked, and a stale `Dispatch` hint
//! re-routes to a healthy replica instead of being %-clamped. Injected
//! scorer faults live in `tests/chaos_serving.rs`.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;
use rilq::coordinator::{ServeConfig, Server};
use rilq::engine::{
    Dispatch, Engine, EngineCaps, EngineConfig, HealthView, Request, RoundRobin, SamplingParams,
    SubmitOptions,
};
use rilq::eval::{greedy_decode, BackendScorer, Scorer};
use rilq::model::backend::BackendKind;
use rilq::model::kv::KvCache;
use rilq::model::{KvArena, ModelDims, StudentWeights, TeacherParams};
use rilq::quant::{by_name, CalibCtx};
use rilq::tensor::{Mat, Rng};

fn dims() -> ModelDims {
    ModelDims {
        name: "serve".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 48,
        seq: 16,
        batch: 4,
        group_size: 8,
    }
}

fn backend_scorer(kind: BackendKind, seed: u64) -> Arc<BackendScorer> {
    let d = dims();
    let mut rng = Rng::seed(seed);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    Arc::new(BackendScorer::new(&d, &teacher, &student, None, kind).unwrap())
}

fn packed_scorer(seed: u64) -> Arc<BackendScorer> {
    backend_scorer(BackendKind::Packed, seed)
}

/// Ragged mix from several client threads: every request answered with
/// the same scores the direct scorer produces, and the token counters
/// prove no PAD-dummy filler was forwarded.
#[test]
fn ragged_mix_every_request_answered_no_pad_waste() {
    let scorer = packed_scorer(41);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(42);
    // includes the degenerate single-token request (empty logp answer)
    let lens = [16usize, 3, 9, 1, 16, 5, 7, 11, 4, 13, 2, 8];
    let requests: Vec<Vec<u32>> = lens
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let want = scorer.score_all(&requests).unwrap();
    let total_tokens: usize = lens.iter().sum();

    let engine = Engine::start_shared(
        scorer.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 8,
            max_active: 4,
            prefill_chunk: 8,
            ..EngineConfig::default()
        },
    );
    // 3 client threads, 4 requests each
    let answers: Vec<Vec<Vec<f32>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|c| {
                let client = engine.client();
                let chunk: Vec<Vec<u32>> = requests[c * 4..(c + 1) * 4].to_vec();
                s.spawn(move || {
                    chunk
                        .into_iter()
                        .map(|r| client.score(r).unwrap().wait().unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let summary = engine.shutdown();

    for (c, got) in answers.iter().enumerate() {
        for (k, logp) in got.iter().enumerate() {
            let expect = &want[c * 4 + k];
            assert_eq!(logp.len(), expect.len(), "request ({c},{k}) wrong length");
            for (a, b) in logp.iter().zip(expect) {
                assert!((a - b).abs() < 1e-5, "request ({c},{k}): {a} vs {b}");
            }
        }
    }
    assert_eq!(summary.requests as usize, lens.len());
    assert_eq!(
        summary.tokens as usize, total_tokens,
        "forwarded tokens != sum of request lengths — PAD-dummy forwards?"
    );
    assert!(summary.batches >= 1.0 && summary.batches <= lens.len() as f64);
    assert!(summary.tokens_per_sec > 0.0, "throughput counter must be > 0");
    assert_eq!(summary.errors, 0.0);
}

/// Malformed requests — over the window, or carrying an out-of-vocab
/// token id (which would index past the embedding table) — are answered
/// with `Err` at admission without killing the engine or poisoning the
/// valid requests around them.
#[test]
fn malformed_requests_err_alone() {
    let scorer = packed_scorer(43);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(44);
    let engine = Engine::start_shared(scorer, EngineConfig::default());
    let client = engine.client();

    let good: Vec<u32> = (0..8).map(|_| rng.below(d.vocab) as u32).collect();
    let too_long: Vec<u32> = (0..d.seq + 5).map(|_| rng.below(d.vocab) as u32).collect();
    let bad_token: Vec<u32> = vec![d.vocab as u32, 0, 1];
    let p1 = client.score(good.clone()).unwrap();
    let p2 = client.score(too_long).unwrap();
    let p3 = client.score(bad_token).unwrap();
    let p4 = client.score(good).unwrap();
    assert_eq!(p1.wait().unwrap().len(), 7);
    let err = p2.wait().unwrap_err();
    assert!(format!("{err}").contains("window"), "{err}");
    let err = p3.wait().unwrap_err();
    assert!(format!("{err}").contains("vocabulary"), "{err}");
    // the loop survived both rejects: later requests still get served
    assert_eq!(p4.wait().unwrap().len(), 7);

    drop(client);
    let summary = engine.shutdown();
    assert_eq!(summary.errors, 2.0);
    assert_eq!(summary.requests, 2.0);
}

/// Gate scorer: blocks inside `score_batch` until opened, recording the
/// batch sizes the loop hands it — lets the test pin coalescing behavior
/// deterministically. Implements only the ragged-batch surface, so its
/// caps are the trait default (no cache, no prefix reuse).
struct GateScorer {
    dims: ModelDims,
    state: Mutex<GateState>,
    cv: Condvar,
}

#[derive(Default)]
struct GateState {
    entered: usize,
    open: bool,
    batch_sizes: Vec<usize>,
}

impl GateScorer {
    fn new(dims: ModelDims) -> GateScorer {
        GateScorer { dims, state: Mutex::new(GateState::default()), cv: Condvar::new() }
    }

    fn wait_entered(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        while st.entered < n {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn open(&self) {
        self.state.lock().unwrap().open = true;
        self.cv.notify_all();
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.state.lock().unwrap().batch_sizes.clone()
    }
}

impl Scorer for GateScorer {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let mut st = self.state.lock().unwrap();
        st.entered += 1;
        st.batch_sizes.push(batch.len());
        self.cv.notify_all();
        while !st.open {
            st = self.cv.wait(st).unwrap();
        }
        drop(st);
        Ok(batch
            .iter()
            .map(|s| vec![-1.0; s.len().saturating_sub(1)])
            .collect())
    }
}

/// Requests arriving while a forward is in flight coalesce into the next
/// batch (up to `max_batch`) instead of running one forward each.
#[test]
fn queued_requests_coalesce_up_to_max_batch() {
    let gate = Arc::new(GateScorer::new(dims()));
    let engine = Engine::start_shared(
        gate.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 4,
            prefill_chunk: 8,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();

    let p0 = client.score(vec![1, 2, 3]).unwrap();
    gate.wait_entered(1); // loop is now blocked inside the first forward
    let pending: Vec<_> =
        (0..7).map(|_| client.score(vec![1, 2, 3, 4]).unwrap()).collect();
    gate.open();
    assert_eq!(p0.wait().unwrap().len(), 2);
    for p in pending {
        assert_eq!(p.wait().unwrap().len(), 3);
    }
    drop(client);
    let summary = engine.shutdown();

    let sizes = gate.batch_sizes();
    assert_eq!(sizes.iter().sum::<usize>(), 8);
    assert_eq!(sizes[0], 1, "first request must not wait for a full batch");
    assert!(
        sizes[1..].iter().all(|&s| s <= 4),
        "batches exceed max_batch: {sizes:?}"
    );
    assert!(
        sizes[1..].iter().any(|&s| s >= 2),
        "queued requests never coalesced: {sizes:?}"
    );
    assert!((summary.mean_occupancy - 8.0 / sizes.len() as f64).abs() < 1e-9);
}

/// A pending answer can be bounded in time: a worker wedged inside the
/// model must surface as a fast `Err`, not a hung test.
#[test]
fn wait_timeout_fails_fast_on_wedged_worker() {
    let gate = Arc::new(GateScorer::new(dims()));
    let engine = Engine::start_shared(gate.clone(), EngineConfig::default());
    let client = engine.client();
    let p = client.score(vec![1, 2, 3]).unwrap();
    gate.wait_entered(1); // the loop is now stuck inside score_batch
    let err = p.wait_timeout(Duration::from_millis(50)).unwrap_err();
    assert!(format!("{err}").contains("within"), "{err}");
    // a timeout consumes nothing: unwedge and the answer still arrives
    gate.open();
    assert_eq!(p.wait_timeout(Duration::from_secs(30)).unwrap().len(), 2);
    drop(client);
    engine.shutdown();
}

/// Dropping the engine drains requests already queued (graceful
/// shutdown), and later submissions err instead of hanging.
#[test]
fn shutdown_drains_queued_requests() {
    let scorer = packed_scorer(45);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(46);
    let engine = Engine::start_shared(
        scorer,
        EngineConfig {
            max_batch: 2,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 8,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    let pendings: Vec<_> = (0..6)
        .map(|_| {
            let seq: Vec<u32> = (0..10).map(|_| rng.below(d.vocab) as u32).collect();
            client.score(seq).unwrap()
        })
        .collect();
    let summary = engine.shutdown(); // queues the sentinel behind the 6 requests
    for p in pendings {
        assert_eq!(p.wait().unwrap().len(), 9);
    }
    assert_eq!(summary.requests, 6.0);
    // the loop is gone: a late submission must err, not hang
    assert!(client.score(vec![1, 2]).is_err());
}

/// Decode mode: generate requests answered through the chunked-prefill +
/// lockstep scheduler match the single-stream greedy decode bit for bit,
/// and the decode metrics/gauges report the scheduler's behavior.
#[test]
fn generate_requests_match_single_stream_decode() {
    let scorer = packed_scorer(47);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(48);
    let prompts: Vec<Vec<u32>> = [5usize, 3, 8, 6, 4]
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let max_new = 6usize;
    let want: Vec<_> = prompts
        .iter()
        .map(|p| greedy_decode(scorer.as_ref(), p, max_new).unwrap())
        .collect();

    // max_active 2 < 5 requests: slots must recycle across generations;
    // prefill_chunk 3 < the longest prompt: chunked prefill must replay
    // the one-shot prefill bitwise
    let engine = Engine::start_shared(
        scorer.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 3,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    let pendings: Vec<_> = prompts
        .iter()
        .map(|p| client.generate(p.clone(), SamplingParams::greedy(max_new)).unwrap())
        .collect();
    let answers: Vec<_> = pendings.into_iter().map(|p| p.wait().unwrap()).collect();
    drop(client);
    let summary = engine.shutdown();

    for (k, (got, (toks, lps))) in answers.iter().zip(&want).enumerate() {
        assert_eq!(&got.tokens, toks, "request {k}: decode diverged");
        assert_eq!(got.logps.len(), lps.len());
        for (a, b) in got.logps.iter().zip(lps) {
            assert!(
                a.to_bits() == b.to_bits(),
                "request {k}: logp not bitwise identical ({a} vs {b})"
            );
        }
    }
    assert_eq!(summary.gen_requests as usize, prompts.len());
    assert_eq!(summary.gen_tokens as usize, prompts.len() * max_new);
    assert_eq!(
        summary.prefill_tokens as usize,
        prompts.iter().map(Vec::len).sum::<usize>(),
        "prefill must forward exactly the prompt tokens, once"
    );
    assert!(summary.decode_steps > 0.0);
    assert!(summary.kv_bytes_peak > 0.0, "KV residency gauge never moved");
    // residency accounting: the gauge now tracks arena blocks actually
    // held, which can never exceed max_active full-window caches
    let cap_bytes = scorer.new_cache().capacity_bytes() as f64;
    assert!(
        summary.kv_bytes_peak <= 2.0 * cap_bytes + 0.5,
        "kv peak {} exceeds max_active * full-window capacity {}",
        summary.kv_bytes_peak,
        2.0 * cap_bytes
    );
    assert!(summary.kv_blocks_peak > 0.0, "block gauge never moved");
    assert_eq!(
        summary.preemptions, 0.0,
        "auto-sized arena fits max_active worst-case sequences — nothing to evict"
    );
    assert!(summary.latency_p95_secs.unwrap() >= summary.latency_p50_secs.unwrap());
    assert!(summary.latency_p50_secs.unwrap() >= 0.0);
    assert_eq!(summary.errors, 0.0);
}

/// Step scorer: a fake cache-capable backend that logs every scheduler
/// call, so tests can pin *when* the engine serves score traffic
/// relative to decode steps.
struct StepScorer {
    dims: ModelDims,
    state: Mutex<Vec<&'static str>>,
    cv: Condvar,
}

impl StepScorer {
    fn new(dims: ModelDims) -> StepScorer {
        StepScorer { dims, state: Mutex::new(Vec::new()), cv: Condvar::new() }
    }

    fn log(&self, ev: &'static str) {
        self.state.lock().unwrap().push(ev);
        self.cv.notify_all();
    }

    fn wait_steps(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        while st.iter().filter(|&&e| e == "step").count() < n {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn events(&self) -> Vec<&'static str> {
        self.state.lock().unwrap().clone()
    }
}

impl Scorer for StepScorer {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn caps(&self) -> EngineCaps {
        EngineCaps::incremental()
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        self.log("score");
        Ok(batch
            .iter()
            .map(|s| vec![-1.0; s.len().saturating_sub(1)])
            .collect())
    }

    fn cache_forward_batch(
        &self,
        news: &[Vec<u32>],
        _caches: &mut [&mut KvCache],
    ) -> Result<Vec<Mat>> {
        self.log("step");
        Ok(news.iter().map(|n| Mat::zeros(n.len(), self.dims.vocab)).collect())
    }
}

/// Acceptance: a short score request submitted while a long generation
/// holds every decode slot is served BETWEEN its decode iterations —
/// the admission scheduler no longer head-of-line blocks intake when
/// `max_active` is saturated.
#[test]
fn score_completes_while_long_generation_holds_decode_slots() {
    let d = ModelDims {
        name: "interleave".into(),
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        vocab: 16,
        seq: 64,
        batch: 4,
        group_size: 8,
    };
    let fake = Arc::new(StepScorer::new(d));
    let engine = Engine::start_shared(
        fake.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 1,
            prefill_chunk: 4,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();

    // one long generation occupies the only decode slot for ~48 steps
    let gen = client.generate(vec![1, 2], SamplingParams::greedy(48)).unwrap();
    fake.wait_steps(2);
    // submitted mid-generation: must be answered without waiting for it
    let score = client.score(vec![1, 2, 3]).unwrap();
    let logp = score
        .wait_timeout(Duration::from_secs(30))
        .expect("score request head-of-line blocked behind a long generation");
    assert_eq!(logp.len(), 2);
    let g = gen.wait().unwrap();
    assert_eq!(g.tokens.len(), 48);
    drop(client);
    engine.shutdown();

    let ev = fake.events();
    let score_at = ev.iter().position(|&e| e == "score").expect("score never ran");
    let last_step = ev.iter().rposition(|&e| e == "step").unwrap();
    assert!(
        score_at < last_step,
        "score was served only after the generation finished: {ev:?}"
    );
    assert!(
        ev[..score_at].iter().filter(|&&e| e == "step").count() >= 2,
        "score was served before any decode step happened: {ev:?}"
    );
}

/// A generate request that cannot fit its budget in the model window —
/// or carries malformed sampling params — is answered with `Err` at
/// admission without poisoning concurrent scoring or decode traffic.
#[test]
fn over_window_generation_errs_alone() {
    let scorer = packed_scorer(49);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(50);
    let engine = Engine::start_shared(
        scorer.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 8,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();

    let prompt: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();
    let score_seq: Vec<u32> = (0..9).map(|_| rng.below(d.vocab) as u32).collect();
    let p_good = client.generate(prompt.clone(), SamplingParams::greedy(4)).unwrap();
    // 6 prompt + (seq) new - 1 > seq: rejected at admission
    let p_over = client.generate(prompt.clone(), SamplingParams::greedy(d.seq)).unwrap();
    let p_empty = client.generate(Vec::new(), SamplingParams::greedy(3)).unwrap();
    let p_zero = client.generate(prompt.clone(), SamplingParams::greedy(0)).unwrap();
    let p_nan = client
        .generate(
            prompt.clone(),
            SamplingParams { temperature: f32::NAN, ..SamplingParams::greedy(2) },
        )
        .unwrap();
    let p_score = client.score(score_seq).unwrap();

    let good = p_good.wait().unwrap();
    assert_eq!(good.tokens.len(), 4);
    let err = p_over.wait().unwrap_err();
    assert!(format!("{err}").contains("window"), "{err}");
    let err = p_empty.wait().unwrap_err();
    assert!(format!("{err}").contains("non-empty"), "{err}");
    let zero = p_zero.wait().unwrap();
    assert!(zero.tokens.is_empty() && zero.logps.is_empty());
    let err = p_nan.wait().unwrap_err();
    assert!(format!("{err}").contains("temperature"), "{err}");
    assert_eq!(p_score.wait().unwrap().len(), 8);

    drop(client);
    let summary = engine.shutdown();
    assert_eq!(summary.errors, 3.0);
    // the zero-budget generation counts as answered, not errored
    assert_eq!(summary.gen_requests, 2.0);
    assert_eq!(summary.requests, 1.0);
}

/// A scorer without KV-cache support (caps without `incremental`, e.g.
/// the fixed-geometry HLO shape) must reject generate requests with a
/// clear error instead of wedging the loop.
#[test]
fn generate_on_cacheless_scorer_errs() {
    let gate = Arc::new(GateScorer::new(dims()));
    gate.open(); // scoring stays live; only generate is rejected
    let engine = Engine::start_shared(gate, EngineConfig::default());
    let client = engine.client();
    let err = client
        .generate(vec![1, 2, 3], SamplingParams::greedy(4))
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(format!("{err}").contains("KV-cache"), "{err}");
    drop(client);
    let summary = engine.shutdown();
    assert_eq!(summary.errors, 1.0);
    assert_eq!(summary.gen_requests, 0.0);
}

/// Two replicas behind a round-robin dispatcher: every request is
/// answered correctly and the shared metrics sink aggregates the fleet.
#[test]
fn sharded_engine_round_robin_serves_all_requests() {
    let a = packed_scorer(51);
    let b = packed_scorer(51); // same seed => identical weights
    let d = a.dims().clone();
    let mut rng = Rng::seed(52);
    let requests: Vec<Vec<u32>> = (0..8)
        .map(|_| (0..10).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let want = a.score_all(&requests).unwrap();

    let replicas: Vec<Arc<dyn Scorer + Send + Sync>> = vec![a, b];
    let engine =
        Engine::start_sharded(replicas, EngineConfig::default(), Arc::new(RoundRobin::new()));
    assert_eq!(engine.n_replicas(), 2);
    let client = engine.client();
    let pendings: Vec<_> = requests.iter().map(|r| client.score(r.clone()).unwrap()).collect();
    for (p, expect) in pendings.into_iter().zip(&want) {
        let got = p.wait().unwrap();
        assert_eq!(got.len(), expect.len());
        for (x, y) in got.iter().zip(expect) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }
    drop(client);
    let summary = engine.shutdown();
    assert_eq!(summary.requests, 8.0);
}

/// The pre-engine `Server`/`ServeClient` verbs still compile and serve,
/// delegating to the engine (deprecation shims).
#[test]
#[allow(deprecated)]
fn deprecated_serve_client_shims_still_serve() {
    let scorer = packed_scorer(53);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(54);
    let seq: Vec<u32> = (0..9).map(|_| rng.below(d.vocab) as u32).collect();
    let prompt: Vec<u32> = (0..4).map(|_| rng.below(d.vocab) as u32).collect();
    let want_score = scorer.score_all(std::slice::from_ref(&seq)).unwrap();
    let (want_toks, _) = greedy_decode(scorer.as_ref(), &prompt, 5).unwrap();

    let server = Server::start_shared(
        scorer,
        ServeConfig {
            max_batch: 4,
            queue_capacity: 8,
            max_active: 2,
            prefill_chunk: 4,
            ..ServeConfig::default()
        },
    );
    let client = server.client();
    let got = client.score(seq.clone()).unwrap();
    assert_eq!(got.len(), want_score[0].len());
    for (x, y) in got.iter().zip(&want_score[0]) {
        assert!((x - y).abs() < 1e-5);
    }
    let pending = client.submit(seq).unwrap();
    assert_eq!(pending.wait().unwrap().len(), 8);
    let gen = client.generate(prompt, 5).unwrap().wait().unwrap();
    assert_eq!(gen.tokens, want_toks);
    let summary = server.shutdown();
    assert_eq!(summary.requests, 2.0);
    assert_eq!(summary.gen_requests, 1.0);
}

/// Gate wrapper over a real backend scorer: delegates every verb, but
/// the fused decode step blocks until released and records how many
/// sequences each step carried — tests pin scheduler concurrency
/// deterministically while the forwards stay real (arena blocks are
/// actually held).
struct GatedScorer {
    inner: Arc<BackendScorer>,
    state: Mutex<GatedState>,
    cv: Condvar,
}

#[derive(Default)]
struct GatedState {
    open: bool,
    entered: usize,
    step_widths: Vec<usize>,
}

impl GatedScorer {
    fn new(inner: Arc<BackendScorer>) -> GatedScorer {
        GatedScorer { inner, state: Mutex::new(GatedState::default()), cv: Condvar::new() }
    }

    fn wait_entered(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        while st.entered < n {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn open(&self) {
        self.state.lock().unwrap().open = true;
        self.cv.notify_all();
    }

    fn step_widths(&self) -> Vec<usize> {
        self.state.lock().unwrap().step_widths.clone()
    }
}

impl Scorer for GatedScorer {
    fn dims(&self) -> &ModelDims {
        self.inner.dims()
    }

    fn caps(&self) -> EngineCaps {
        self.inner.caps()
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        self.inner.score_batch(batch)
    }

    fn cache_forward_batch(
        &self,
        news: &[Vec<u32>],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Mat>> {
        {
            let mut st = self.state.lock().unwrap();
            st.entered += 1;
            st.step_widths.push(news.len());
            self.cv.notify_all();
            while !st.open {
                st = self.cv.wait(st).unwrap();
            }
        }
        self.inner.cache_forward_batch(news, caches)
    }
}

/// Tentpole acceptance: paging lifts decode concurrency from the worst
/// case to actual residency. The arena holds 2 full-window sequences
/// (8 blocks of 4 positions against seq 16), yet 4 short generations —
/// one block each at their longest — decode concurrently in a single
/// fused step, and the `serve.kv_bytes` gauge tracks blocks in use, far
/// below the old `max_active × full-window` accounting.
#[test]
fn short_generations_pack_beyond_worst_case_concurrency() {
    let scorer = packed_scorer(55);
    let d = scorer.dims().clone();
    let gated = Arc::new(GatedScorer::new(scorer.clone()));
    let mut rng = Rng::seed(56);
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|_| (0..2).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let max_new = 3usize;
    let want: Vec<_> = prompts
        .iter()
        .map(|p| greedy_decode(scorer.as_ref(), p, max_new).unwrap())
        .collect();

    let engine = Engine::start_shared(
        gated.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 4,
            prefill_chunk: 8,
            kv_block: 4,
            arena_blocks: 8,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    // the first generation reaches the (gated) fused step and blocks
    // there; the rest queue while the loop is inside the forward, so the
    // next scheduler round promotes all of them at once
    let first = client.generate(prompts[0].clone(), SamplingParams::greedy(max_new)).unwrap();
    gated.wait_entered(1);
    let rest: Vec<_> = prompts[1..]
        .iter()
        .map(|p| client.generate(p.clone(), SamplingParams::greedy(max_new)).unwrap())
        .collect();
    gated.open();
    let mut answers = vec![first.wait().unwrap()];
    answers.extend(rest.into_iter().map(|p| p.wait().unwrap()));
    drop(client);
    let summary = engine.shutdown();

    for (k, (got, (toks, _))) in answers.iter().zip(&want).enumerate() {
        assert_eq!(&got.tokens, toks, "request {k}: decode diverged");
    }
    assert!(
        gated.step_widths().iter().any(|&w| w == 4),
        "4 generations never shared one fused step: {:?}",
        gated.step_widths()
    );
    let arena = KvArena::new(&d, 4, 8);
    assert!(summary.kv_blocks_peak >= 4.0, "each resident decode holds at least one block");
    assert!(summary.kv_blocks_peak <= 8.0, "block gauge exceeded the arena");
    assert!(
        summary.kv_bytes_peak <= 8.0 * arena.block_bytes() as f64,
        "kv_bytes must track blocks in use, bounded by the arena"
    );
    assert!(
        summary.kv_bytes_peak < 4.0 * scorer.new_cache().capacity_bytes() as f64,
        "kv_bytes gauge still prices residency at the full-window worst case"
    );
    assert_eq!(summary.preemptions, 0.0, "one block per sequence fits — nothing to evict");
    assert_eq!(summary.errors, 0.0);
}

/// Tentpole acceptance: a generation evicted from the arena under
/// memory pressure resumes bit-exact — tokens and logps equal the
/// uninterrupted `greedy_decode` on every backend, the preemption
/// counter proves evictions actually happened, and score traffic
/// submitted while the arena thrashes is still served between steps.
#[test]
fn preempted_generation_resumes_bitwise_identical_on_every_backend() {
    for kind in BackendKind::ALL {
        let scorer = backend_scorer(kind, 57);
        let d = scorer.dims().clone();
        let gated = Arc::new(GatedScorer::new(scorer.clone()));
        let mut rng = Rng::seed(58);
        let prompts: Vec<Vec<u32>> = (0..2)
            .map(|_| (0..4).map(|_| rng.below(d.vocab) as u32).collect())
            .collect();
        let max_new = 8usize;
        let want: Vec<_> = prompts
            .iter()
            .map(|p| greedy_decode(scorer.as_ref(), p, max_new).unwrap())
            .collect();
        let score_seq: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();

        // each generation peaks at 11 positions = 3 blocks of 4; a
        // 4-block arena cannot hold both at their longest, so the
        // scheduler must evict one mid-decode and replay it later
        let engine = Engine::start_shared(
            gated.clone(),
            EngineConfig {
                max_batch: 4,
                queue_capacity: 16,
                max_active: 2,
                prefill_chunk: 2,
                kv_block: 4,
                arena_blocks: 4,
                ..EngineConfig::default()
            },
        );
        let client = engine.client();
        let p0 = client.generate(prompts[0].clone(), SamplingParams::greedy(max_new)).unwrap();
        gated.wait_entered(1); // gen 0 is inside its first prefill chunk
        let p1 = client.generate(prompts[1].clone(), SamplingParams::greedy(max_new)).unwrap();
        gated.open();
        let p_score = client.score(score_seq).unwrap();
        let logp = p_score
            .wait_timeout(Duration::from_secs(30))
            .expect("score request starved while the arena was under pressure");
        assert_eq!(logp.len(), 5);
        let answers = [p0.wait().unwrap(), p1.wait().unwrap()];
        drop(client);
        let summary = engine.shutdown();

        assert!(
            summary.preemptions >= 1.0,
            "[{kind:?}] the undersized arena never forced an eviction"
        );
        for (k, (got, (toks, lps))) in answers.iter().zip(&want).enumerate() {
            assert_eq!(
                &got.tokens, toks,
                "[{kind:?}] request {k}: tokens diverged after preemption"
            );
            assert_eq!(got.logps.len(), lps.len());
            for (a, b) in got.logps.iter().zip(lps) {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "[{kind:?}] request {k}: logp not bitwise identical ({a} vs {b})"
                );
            }
        }
        assert_eq!(summary.gen_requests, 2.0);
        assert_eq!(summary.errors, 0.0);
    }
}

/// A generation whose worst-case residency cannot fit the arena even
/// running alone is rejected at admission with a clear error — and the
/// rejection starves nothing: a fitting generation and concurrent score
/// traffic are served normally.
#[test]
fn over_arena_generation_errs_alone() {
    let scorer = packed_scorer(59);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(60);
    let engine = Engine::start_shared(
        scorer.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            kv_block: 4,
            arena_blocks: 2, // 8 positions total
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    let prompt: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();
    let score_seq: Vec<u32> = (0..9).map(|_| rng.below(d.vocab) as u32).collect();

    // 6 prompt + 4 new - 1 = 9 positions = 3 blocks > the 2-block arena
    // (but within the model window: only the arena check can reject it)
    let p_over = client.generate(prompt.clone(), SamplingParams::greedy(4)).unwrap();
    // 6 + 3 - 1 = 8 positions = exactly the 2 blocks the arena holds
    let p_fit = client.generate(prompt.clone(), SamplingParams::greedy(3)).unwrap();
    let p_score = client.score(score_seq).unwrap();

    let err = p_over.wait().unwrap_err();
    assert!(format!("{err}").contains("arena"), "{err}");
    let (want_toks, _) = greedy_decode(scorer.as_ref(), &prompt, 3).unwrap();
    assert_eq!(p_fit.wait().unwrap().tokens, want_toks);
    assert_eq!(p_score.wait().unwrap().len(), 8);

    drop(client);
    let summary = engine.shutdown();
    assert_eq!(summary.errors, 1.0);
    assert_eq!(summary.gen_requests, 1.0);
    assert_eq!(summary.requests, 1.0);
}

/// Spin until `ok` holds (the engine loop aborts abandoned work at its
/// next step boundary, not synchronously with the drop/cancel).
fn poll_until(budget: Duration, mut ok: impl FnMut() -> bool) -> bool {
    let t0 = std::time::Instant::now();
    while t0.elapsed() < budget {
        if ok() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    ok()
}

/// Regression (orphaned-generation leak): dropping a `Pending` mid-decode
/// must abort the generation at the next step boundary and return its
/// arena blocks — not let it decode to completion (or worse, hold KV
/// blocks forever) computing an answer nobody will read.
#[test]
fn dropped_pending_aborts_the_generation_and_frees_its_blocks() {
    let scorer = packed_scorer(61);
    let gated = Arc::new(GatedScorer::new(scorer));
    let engine = Engine::start_shared(
        gated.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            ..EngineConfig::default()
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();
    let p = client.generate(vec![1, 2, 3, 4], SamplingParams::greedy(8)).unwrap();
    gated.wait_entered(1); // the prefill step is in flight, blocks are held
    assert!(arena.blocks_in_use() > 0, "the prefill step must hold arena blocks");
    drop(p); // abandon: the loop sees it at the next reap, before step 2
    gated.open();
    assert!(
        poll_until(Duration::from_secs(10), || arena.blocks_in_use() == 0),
        "abandoned generation still holds {} arena block(s)",
        arena.blocks_in_use()
    );
    drop(client);
    let summary = engine.shutdown();
    assert!(summary.cancelled >= 1.0, "serve.cancelled never counted the abandoned request");
    assert_eq!(summary.gen_requests, 0.0, "the abandoned generation must not finish");
    assert_eq!(arena.blocks_in_use(), 0);
}

/// `Pending::cancel` aborts a mid-decode generation at the next step
/// boundary: the handle resolves with the cancellation `Err` and the
/// generation's arena blocks return to the pool.
#[test]
fn pending_cancel_aborts_mid_decode() {
    let scorer = packed_scorer(62);
    let gated = Arc::new(GatedScorer::new(scorer));
    let engine = Engine::start_shared(
        gated.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            ..EngineConfig::default()
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();
    let p = client.generate(vec![1, 2, 3, 4], SamplingParams::greedy(8)).unwrap();
    gated.wait_entered(1);
    p.cancel();
    gated.open();
    let err = p.wait().unwrap_err();
    assert!(format!("{err}").contains("cancelled"), "{err}");
    assert!(
        poll_until(Duration::from_secs(10), || arena.blocks_in_use() == 0),
        "cancelled generation still holds {} arena block(s)",
        arena.blocks_in_use()
    );
    drop(client);
    let summary = engine.shutdown();
    assert!(summary.cancelled >= 1.0);
    assert_eq!(summary.gen_requests, 0.0);
}

/// A queued score request whose deadline expires before the loop reaches
/// it is shed with `Err` — it never costs a forward (`serve.shed`), and
/// traffic around it is unaffected.
#[test]
fn queued_score_past_deadline_is_shed() {
    let gate = Arc::new(GateScorer::new(dims()));
    let engine = Engine::start_shared(gate.clone(), EngineConfig::default());
    let client = engine.client();
    let p0 = client.score(vec![1, 2, 3]).unwrap();
    gate.wait_entered(1); // the loop is wedged inside p0's forward
    let doomed = client
        .score_with(vec![1, 2, 3, 4], &SubmitOptions::with_deadline(Duration::from_millis(10)))
        .unwrap();
    let fine = client.score(vec![1, 2, 3, 4]).unwrap();
    std::thread::sleep(Duration::from_millis(40)); // the deadline passes in queue
    gate.open();
    assert_eq!(p0.wait().unwrap().len(), 2);
    let err = doomed.wait().unwrap_err();
    assert!(format!("{err}").contains("deadline expired"), "{err}");
    assert_eq!(fine.wait().unwrap().len(), 3, "deadline-free neighbor must be served");
    drop(client);
    let summary = engine.shutdown();
    assert!(summary.shed >= 1.0, "serve.shed never counted the expired request");
    // shed is not an admission error: the request was well-formed
    assert_eq!(summary.errors, 0.0);
    // the doomed request's tokens were never forwarded
    let sizes = gate.batch_sizes();
    assert_eq!(sizes.iter().sum::<usize>(), 2, "the shed request reached the scorer: {sizes:?}");
}

/// `EngineConfig::default_deadline` applies to every submission without
/// its own deadline, and a generation it expires mid-decode is aborted
/// at the step boundary (`serve.deadline_aborts`), freeing its blocks.
#[test]
fn default_deadline_aborts_generation_mid_decode() {
    let scorer = packed_scorer(63);
    let gated = Arc::new(GatedScorer::new(scorer));
    let engine = Engine::start_shared(
        gated.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            default_deadline: Some(Duration::from_millis(40)),
            ..EngineConfig::default()
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();
    // prompt fits one prefill chunk: step 1 completes prefill AND samples
    // the first token, so decode has begun when the deadline expires
    let p = client.generate(vec![1, 2, 3, 4], SamplingParams::greedy(8)).unwrap();
    gated.wait_entered(1);
    std::thread::sleep(Duration::from_millis(80)); // deadline passes mid-step
    gated.open();
    let err = p.wait().unwrap_err();
    assert!(format!("{err}").contains("deadline expired mid-generation"), "{err}");
    assert!(
        poll_until(Duration::from_secs(10), || arena.blocks_in_use() == 0),
        "deadline-aborted generation still holds {} arena block(s)",
        arena.blocks_in_use()
    );
    drop(client);
    let summary = engine.shutdown();
    assert!(summary.deadline_aborts >= 1.0, "serve.deadline_aborts never counted the abort");
    assert_eq!(summary.gen_requests, 0.0);
}

/// Shutdown under load: a mixed Score/Generate burst with shutdown
/// racing mid-decode still resolves every `Pending` (Ok from the drain —
/// never a hang) and returns every KV arena block, on every backend.
#[test]
fn shutdown_under_load_resolves_every_pending_across_backends() {
    for kind in BackendKind::ALL {
        let scorer = backend_scorer(kind, 64);
        let d = scorer.dims().clone();
        let mut rng = Rng::seed(65);
        let engine = Engine::start_shared(
            scorer,
            EngineConfig {
                max_batch: 4,
                queue_capacity: 16,
                max_active: 2,
                prefill_chunk: 2,
                kv_block: 4,
                arena_blocks: 4, // undersized: preemption can race shutdown too
                ..EngineConfig::default()
            },
        );
        let arenas: Vec<_> = engine.arenas().to_vec();
        let client = engine.client();
        let scores: Vec<_> = (0..6)
            .map(|_| {
                let s: Vec<u32> = (0..8).map(|_| rng.below(d.vocab) as u32).collect();
                client.score(s).unwrap()
            })
            .collect();
        let gens: Vec<_> = (0..4)
            .map(|_| {
                let p: Vec<u32> = (0..4).map(|_| rng.below(d.vocab) as u32).collect();
                client.generate(p, SamplingParams::greedy(6)).unwrap()
            })
            .collect();
        // the sentinel queues behind the burst: everything already
        // submitted must drain to an answer before the loops exit
        let summary = engine.shutdown();
        for (k, p) in scores.into_iter().enumerate() {
            let got = p
                .wait_timeout(Duration::from_secs(30))
                .unwrap_or_else(|e| panic!("[{kind:?}] score {k} unresolved: {e}"));
            assert_eq!(got.len(), 7);
        }
        for (k, g) in gens.into_iter().enumerate() {
            let got = g
                .wait_timeout(Duration::from_secs(30))
                .unwrap_or_else(|e| panic!("[{kind:?}] generation {k} unresolved: {e}"));
            assert_eq!(got.tokens.len(), 6);
        }
        for (i, a) in arenas.iter().enumerate() {
            assert_eq!(
                a.blocks_in_use(),
                0,
                "[{kind:?}] replica {i} leaked arena blocks through shutdown"
            );
        }
        assert_eq!(summary.errors, 0.0, "[{kind:?}] the drain answered something Err");
    }
}

/// Tentpole acceptance (cross-request prefix cache): a mixed wave of
/// requests sharing an 8-token system prompt plus cold requests drains
/// with the shared requests hitting the radix index — `prefix_hits` and
/// `prefix_tokens_saved` fire, forwarded prefill rows shrink by exactly
/// the saved tokens, every answer is bitwise identical to the
/// uninterrupted single-stream decode, and shutdown leaves zero arena
/// blocks in use (the index's pins included — no refcount leaks).
#[test]
fn shared_prefix_traffic_hits_cache_and_drains_bitwise() {
    let scorer = packed_scorer(70);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(71);
    // 8 shared tokens = 2 whole blocks of 4; per-request 2-token suffixes
    let sys: Vec<u32> = (0..8).map(|_| rng.below(d.vocab) as u32).collect();
    let shared_prompts: Vec<Vec<u32>> = (0..3)
        .map(|_| {
            let mut p = sys.clone();
            p.extend((0..2).map(|_| rng.below(d.vocab) as u32));
            p
        })
        .collect();
    let cold_prompts: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..6).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let max_new = 4usize;
    let all_prompts: Vec<Vec<u32>> =
        shared_prompts.iter().chain(&cold_prompts).cloned().collect();
    let want: Vec<_> = all_prompts
        .iter()
        .map(|p| greedy_decode(scorer.as_ref(), p, max_new).unwrap())
        .collect();

    let engine = Engine::start_shared(
        scorer.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 4,
            prefill_chunk: 4,
            kv_block: 4,
            ..EngineConfig::default() // arena auto-sized: nothing preempts
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();
    // the warm request prefills the shared prompt cold; completing its
    // prefill publishes the committed blocks, so it is awaited before
    // the mixed shared/cold wave goes in
    let warm = client
        .generate(all_prompts[0].clone(), SamplingParams::greedy(max_new))
        .unwrap()
        .wait()
        .unwrap();
    let wave: Vec<_> = all_prompts[1..]
        .iter()
        .map(|p| client.generate(p.clone(), SamplingParams::greedy(max_new)).unwrap())
        .collect();
    let mut answers = vec![warm];
    answers.extend(wave.into_iter().map(|p| p.wait().unwrap()));
    drop(client);
    let summary = engine.shutdown();

    for (k, (got, (toks, lps))) in answers.iter().zip(&want).enumerate() {
        assert_eq!(&got.tokens, toks, "request {k}: cached-prefix decode diverged");
        assert_eq!(got.logps.len(), lps.len());
        for (a, b) in got.logps.iter().zip(lps) {
            assert!(
                a.to_bits() == b.to_bits(),
                "request {k}: logp not bitwise identical ({a} vs {b})"
            );
        }
    }
    // the two later shared requests each attach the 2-block (8-token)
    // system prompt; the cold requests miss
    assert!(summary.prefix_hits >= 2.0, "prefix hits: {}", summary.prefix_hits);
    assert!(
        summary.prefix_tokens_saved >= 16.0,
        "tokens saved: {}",
        summary.prefix_tokens_saved
    );
    // saved rows were never forwarded: prefill counters account exactly
    let total_prompt: usize = all_prompts.iter().map(Vec::len).sum();
    assert_eq!(
        summary.prefill_tokens + summary.prefix_tokens_saved,
        total_prompt as f64,
        "forwarded prefill rows + saved rows must cover every prompt token once"
    );
    assert_eq!(summary.preemptions, 0.0);
    assert_eq!(summary.errors, 0.0);
    // the drain releases every pin: no refcount leaks
    assert_eq!(summary.kv_blocks_pinned, 0.0, "index pins survived shutdown");
    assert_eq!(arena.blocks_in_use(), 0, "arena blocks leaked through shutdown");
}

/// A dispatch policy that always returns the same hint — out of range or
/// pointing at an unhealthy replica — exercising the client's re-route
/// path (the fix for the old `route(..) % txs.len()` silent clamp).
struct Sticky(usize);

impl Dispatch for Sticky {
    fn route(&self, _req: &Request, _health: &HealthView) -> usize {
        self.0
    }
}

/// A stale or out-of-range `Dispatch` hint is re-routed to a healthy
/// replica instead of being %-clamped into a slot that may be dead; with
/// no healthy replica left, submission refuses with a clear error.
#[test]
fn stale_dispatch_hint_reroutes_to_a_healthy_replica() {
    let a = packed_scorer(66);
    let b = packed_scorer(66); // same seed => identical weights
    let want = a.score_all(&[vec![1, 2, 3]]).unwrap();
    let replicas: Vec<Arc<dyn Scorer + Send + Sync>> = vec![a, b];
    // hint 7 is out of range for a 2-replica fleet on every submission
    let engine = Engine::start_sharded(replicas, EngineConfig::default(), Arc::new(Sticky(7)));
    let health = engine.health();
    let client = engine.client();
    let got = client.score(vec![1, 2, 3]).unwrap().wait().unwrap();
    assert_eq!(got.len(), want[0].len(), "out-of-range hint must re-route, not clamp");
    // 7 % 2 = 1 would be the old clamp target; with replica 1 unhealthy
    // the submission must land on replica 0 instead
    health.mark_unhealthy(1);
    assert_eq!(client.score(vec![1, 2, 3]).unwrap().wait().unwrap().len(), want[0].len());
    // no healthy replica left: refuse at submission, don't enqueue into
    // a fleet that can never answer
    health.mark_unhealthy(0);
    let err = client.score(vec![1, 2, 3]).unwrap_err();
    assert!(format!("{err}").contains("no healthy replica"), "{err}");
    engine.shutdown();
}
