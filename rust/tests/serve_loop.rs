//! Integration tests for the continuous-batching serve loop
//! (`coordinator::serve`): ragged request mixes are answered correctly
//! with no PAD-dummy forwards, coalescing actually happens under load,
//! bad requests don't poison their batchmates, shutdown drains, and the
//! KV-cache decode mode (prefill + lockstep round-robin steps) matches
//! the single-stream greedy decode while respecting its cache-slot
//! budget.

use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;
use rilq::coordinator::{ServeConfig, Server};
use rilq::eval::{greedy_decode, BackendScorer, Scorer};
use rilq::model::backend::BackendKind;
use rilq::model::{ModelDims, StudentWeights, TeacherParams};
use rilq::quant::{by_name, CalibCtx};
use rilq::tensor::Rng;

fn dims() -> ModelDims {
    ModelDims {
        name: "serve".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 48,
        seq: 16,
        batch: 4,
        group_size: 8,
    }
}

fn packed_scorer(seed: u64) -> Arc<BackendScorer> {
    let d = dims();
    let mut rng = Rng::seed(seed);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    Arc::new(BackendScorer::new(&d, &teacher, &student, None, BackendKind::Packed).unwrap())
}

/// Ragged mix from several client threads: every request answered with
/// the same scores the direct scorer produces, and the token counters
/// prove no PAD-dummy filler was forwarded.
#[test]
fn ragged_mix_every_request_answered_no_pad_waste() {
    let scorer = packed_scorer(41);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(42);
    // includes the degenerate single-token request (empty logp answer)
    let lens = [16usize, 3, 9, 1, 16, 5, 7, 11, 4, 13, 2, 8];
    let requests: Vec<Vec<u32>> = lens
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let want = scorer.score_all(&requests).unwrap();
    let total_tokens: usize = lens.iter().sum();

    let server = Server::start_shared(
        scorer.clone(),
        ServeConfig { max_batch: 4, queue_capacity: 8, max_active: 4 },
    );
    // 3 client threads, 4 requests each
    let answers: Vec<Vec<Vec<f32>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|c| {
                let client = server.client();
                let chunk: Vec<Vec<u32>> = requests[c * 4..(c + 1) * 4].to_vec();
                s.spawn(move || {
                    chunk
                        .into_iter()
                        .map(|r| client.score(r).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let summary = server.shutdown();

    for (c, got) in answers.iter().enumerate() {
        for (k, logp) in got.iter().enumerate() {
            let expect = &want[c * 4 + k];
            assert_eq!(logp.len(), expect.len(), "request ({c},{k}) wrong length");
            for (a, b) in logp.iter().zip(expect) {
                assert!((a - b).abs() < 1e-5, "request ({c},{k}): {a} vs {b}");
            }
        }
    }
    assert_eq!(summary.requests as usize, lens.len());
    assert_eq!(
        summary.tokens as usize, total_tokens,
        "forwarded tokens != sum of request lengths — PAD-dummy forwards?"
    );
    assert!(summary.batches >= 1.0 && summary.batches <= lens.len() as f64);
    assert!(summary.tokens_per_sec > 0.0, "throughput counter must be > 0");
    assert_eq!(summary.errors, 0.0);
}

/// Malformed requests — over the window, or carrying an out-of-vocab
/// token id (which would index past the embedding table) — are answered
/// with `Err` without killing the serve thread or poisoning the valid
/// requests around them.
#[test]
fn malformed_requests_err_alone() {
    let scorer = packed_scorer(43);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(44);
    let server = Server::start_shared(scorer, ServeConfig::default());
    let client = server.client();

    let good: Vec<u32> = (0..8).map(|_| rng.below(d.vocab) as u32).collect();
    let too_long: Vec<u32> = (0..d.seq + 5).map(|_| rng.below(d.vocab) as u32).collect();
    let bad_token: Vec<u32> = vec![d.vocab as u32, 0, 1];
    let p1 = client.submit(good.clone()).unwrap();
    let p2 = client.submit(too_long).unwrap();
    let p3 = client.submit(bad_token).unwrap();
    let p4 = client.submit(good).unwrap();
    assert_eq!(p1.wait().unwrap().len(), 7);
    let err = p2.wait().unwrap_err();
    assert!(format!("{err}").contains("window"), "{err}");
    let err = p3.wait().unwrap_err();
    assert!(format!("{err}").contains("vocabulary"), "{err}");
    // the loop survived both rejects: later requests still get served
    assert_eq!(p4.wait().unwrap().len(), 7);

    drop(client);
    let summary = server.shutdown();
    assert_eq!(summary.errors, 2.0);
    assert_eq!(summary.requests, 2.0);
}

/// Gate scorer: blocks inside `score_batch` until opened, recording the
/// batch sizes the loop hands it — lets the test pin coalescing behavior
/// deterministically.
struct GateScorer {
    dims: ModelDims,
    state: Mutex<GateState>,
    cv: Condvar,
}

#[derive(Default)]
struct GateState {
    entered: usize,
    open: bool,
    batch_sizes: Vec<usize>,
}

impl GateScorer {
    fn new(dims: ModelDims) -> GateScorer {
        GateScorer { dims, state: Mutex::new(GateState::default()), cv: Condvar::new() }
    }

    fn wait_entered(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        while st.entered < n {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn open(&self) {
        self.state.lock().unwrap().open = true;
        self.cv.notify_all();
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.state.lock().unwrap().batch_sizes.clone()
    }
}

impl Scorer for GateScorer {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let mut st = self.state.lock().unwrap();
        st.entered += 1;
        st.batch_sizes.push(batch.len());
        self.cv.notify_all();
        while !st.open {
            st = self.cv.wait(st).unwrap();
        }
        drop(st);
        Ok(batch
            .iter()
            .map(|s| vec![-1.0; s.len().saturating_sub(1)])
            .collect())
    }
}

/// Requests arriving while a forward is in flight coalesce into the next
/// batch (up to `max_batch`) instead of running one forward each.
#[test]
fn queued_requests_coalesce_up_to_max_batch() {
    let gate = Arc::new(GateScorer::new(dims()));
    let server = Server::start_shared(
        gate.clone(),
        ServeConfig { max_batch: 4, queue_capacity: 16, max_active: 4 },
    );
    let client = server.client();

    let p0 = client.submit(vec![1, 2, 3]).unwrap();
    gate.wait_entered(1); // loop is now blocked inside the first forward
    let pending: Vec<_> =
        (0..7).map(|_| client.submit(vec![1, 2, 3, 4]).unwrap()).collect();
    gate.open();
    assert_eq!(p0.wait().unwrap().len(), 2);
    for p in pending {
        assert_eq!(p.wait().unwrap().len(), 3);
    }
    drop(client);
    let summary = server.shutdown();

    let sizes = gate.batch_sizes();
    assert_eq!(sizes.iter().sum::<usize>(), 8);
    assert_eq!(sizes[0], 1, "first request must not wait for a full batch");
    assert!(
        sizes[1..].iter().all(|&s| s <= 4),
        "batches exceed max_batch: {sizes:?}"
    );
    assert!(
        sizes[1..].iter().any(|&s| s >= 2),
        "queued requests never coalesced: {sizes:?}"
    );
    assert!((summary.mean_occupancy - 8.0 / sizes.len() as f64).abs() < 1e-9);
}

/// Dropping the server drains requests already queued (graceful
/// shutdown), and later submissions err instead of hanging.
#[test]
fn shutdown_drains_queued_requests() {
    let scorer = packed_scorer(45);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(46);
    let server = Server::start_shared(
        scorer,
        ServeConfig { max_batch: 2, queue_capacity: 16, max_active: 2 },
    );
    let client = server.client();
    let pendings: Vec<_> = (0..6)
        .map(|_| {
            let seq: Vec<u32> = (0..10).map(|_| rng.below(d.vocab) as u32).collect();
            client.submit(seq).unwrap()
        })
        .collect();
    let summary = server.shutdown(); // queues the sentinel behind the 6 requests
    for p in pendings {
        assert_eq!(p.wait().unwrap().len(), 9);
    }
    assert_eq!(summary.requests, 6.0);
    // the loop is gone: a late submission must err, not hang
    assert!(client.submit(vec![1, 2]).is_err() || client.score(vec![1, 2]).is_err());
}

/// Decode mode: generate requests answered through the lockstep
/// round-robin scheduler match the single-stream greedy decode bit for
/// bit, and the decode metrics/gauges report the scheduler's behavior.
#[test]
fn generate_requests_match_single_stream_decode() {
    let scorer = packed_scorer(47);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(48);
    let prompts: Vec<Vec<u32>> = [5usize, 3, 8, 6, 4]
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let max_new = 6usize;
    let want: Vec<_> = prompts
        .iter()
        .map(|p| greedy_decode(scorer.as_ref(), p, max_new).unwrap())
        .collect();

    // max_active 2 < 5 requests: slots must recycle across generations
    let server = Server::start_shared(
        scorer.clone(),
        ServeConfig { max_batch: 4, queue_capacity: 16, max_active: 2 },
    );
    let client = server.client();
    let pendings: Vec<_> = prompts
        .iter()
        .map(|p| client.generate(p.clone(), max_new).unwrap())
        .collect();
    let answers: Vec<_> = pendings.into_iter().map(|p| p.wait().unwrap()).collect();
    drop(client);
    let summary = server.shutdown();

    for (k, (got, (toks, lps))) in answers.iter().zip(&want).enumerate() {
        assert_eq!(&got.tokens, toks, "request {k}: decode diverged");
        assert_eq!(got.logps.len(), lps.len());
        for (a, b) in got.logps.iter().zip(lps) {
            assert!((a - b).abs() < 1e-5, "request {k}: {a} vs {b}");
        }
    }
    assert_eq!(summary.gen_requests as usize, prompts.len());
    assert_eq!(summary.gen_tokens as usize, prompts.len() * max_new);
    assert_eq!(
        summary.prefill_tokens as usize,
        prompts.iter().map(Vec::len).sum::<usize>(),
        "prefill must forward exactly the prompt tokens, once"
    );
    assert!(summary.decode_steps > 0.0);
    assert!(summary.kv_bytes_peak > 0.0, "KV residency gauge never moved");
    // cache-capacity accounting: never more than max_active caches resident
    let cache_bytes = scorer.new_cache().bytes() as f64;
    assert!(
        summary.kv_bytes_peak <= 2.0 * cache_bytes + 0.5,
        "kv peak {} exceeds max_active * per-cache bytes {}",
        summary.kv_bytes_peak,
        2.0 * cache_bytes
    );
    assert!(summary.latency_p95_secs >= summary.latency_p50_secs);
    assert!(summary.latency_p50_secs >= 0.0);
    assert_eq!(summary.errors, 0.0);
}

/// A generate request that cannot fit its budget in the model window is
/// answered with `Err` at admission without poisoning concurrent scoring
/// or decode traffic (mixed-workload loop survival).
#[test]
fn over_window_generation_errs_alone() {
    let scorer = packed_scorer(49);
    let d = scorer.dims().clone();
    let mut rng = Rng::seed(50);
    let server = Server::start_shared(
        scorer.clone(),
        ServeConfig { max_batch: 4, queue_capacity: 16, max_active: 2 },
    );
    let client = server.client();

    let prompt: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();
    let score_seq: Vec<u32> = (0..9).map(|_| rng.below(d.vocab) as u32).collect();
    let p_good = client.generate(prompt.clone(), 4).unwrap();
    // 6 prompt + (seq) new - 1 > seq: rejected at admission
    let p_over = client.generate(prompt.clone(), d.seq).unwrap();
    let p_empty = client.generate(Vec::new(), 3).unwrap();
    let p_zero = client.generate(prompt.clone(), 0).unwrap();
    let p_score = client.submit(score_seq).unwrap();

    let good = p_good.wait().unwrap();
    assert_eq!(good.tokens.len(), 4);
    let err = p_over.wait().unwrap_err();
    assert!(format!("{err}").contains("window"), "{err}");
    let err = p_empty.wait().unwrap_err();
    assert!(format!("{err}").contains("non-empty"), "{err}");
    let zero = p_zero.wait().unwrap();
    assert!(zero.tokens.is_empty() && zero.logps.is_empty());
    assert_eq!(p_score.wait().unwrap().len(), 8);

    drop(client);
    let summary = server.shutdown();
    assert_eq!(summary.errors, 2.0);
    // the zero-budget generation counts as answered, not errored
    assert_eq!(summary.gen_requests, 2.0);
    assert_eq!(summary.requests, 1.0);
}

/// A scorer without KV-cache support (the fixed-geometry HLO shape,
/// simulated by GateScorer's defaults) must reject generate requests
/// with a clear error instead of wedging the loop.
#[test]
fn generate_on_cacheless_scorer_errs() {
    let gate = Arc::new(GateScorer::new(dims()));
    let server = Server::start_shared(gate, ServeConfig::default());
    let client = server.client();
    let err = client.generate(vec![1, 2, 3], 4).unwrap().wait().unwrap_err();
    assert!(format!("{err}").contains("KV-cache"), "{err}");
    drop(client);
    let summary = server.shutdown();
    assert_eq!(summary.errors, 1.0);
    assert_eq!(summary.gen_requests, 0.0);
}
