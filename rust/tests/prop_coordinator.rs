//! Property tests on coordinator + substrate invariants (hand-rolled,
//! PCG-driven — the offline crate set has no proptest). Each property runs
//! 50–200 randomized cases.

use rilq::coordinator::batcher::BatchStream;
use rilq::coordinator::cache::fnv64;
use rilq::data::tasks::{gen_gsm, gen_mc, TaskKind};
use rilq::data::{Corpus, Profile, Vocab};
use rilq::lqec::{AdapterSet, GroupedAdapterSet};
use rilq::model::{ModelDims, TeacherParams};
use rilq::quant::{by_name, pack_codes, unpack_codes, CalibCtx};
use rilq::report::Json;
use rilq::tensor::{Mat, Rng};

fn dims_for(rng: &mut Rng) -> ModelDims {
    let heads = [1usize, 2, 4][rng.below(3)];
    let d_model = heads * 8 * (1 + rng.below(2));
    ModelDims {
        name: "prop".into(),
        d_model,
        n_layers: 1 + rng.below(3),
        n_heads: heads,
        d_ff: 16 * (1 + rng.below(3)),
        vocab: 64,
        seq: 16,
        batch: 2,
        group_size: 8,
    }
}

/// Batcher: deterministic, exact geometry, produces exactly `limit`
/// batches, never loses or duplicates tokens relative to a direct corpus
/// stream with the same seed.
#[test]
fn prop_batcher_conservation() {
    let mut meta = Rng::seed(0xba7c);
    for _ in 0..20 {
        let seed = meta.next_u64();
        let batch = 1 + meta.below(4);
        let seq = 8 + meta.below(24);
        let limit = 1 + meta.below(6);
        let vocab = Vocab::new(256, 1);
        let mut s = BatchStream::spawn(
            vocab.clone(),
            Profile::C4Sim,
            seed,
            batch,
            seq,
            limit,
            2,
        );
        let mut corpus = Corpus::new(vocab, Profile::C4Sim, seed);
        let mut n = 0;
        while let Some(b) = s.next() {
            let want = corpus.sample_batch(batch, seq);
            assert_eq!(b, want, "stream diverged from direct corpus");
            n += 1;
        }
        assert_eq!(n, limit);
    }
}

/// Packing: roundtrip over random geometries and bit widths.
#[test]
fn prop_packing_roundtrip() {
    let mut rng = Rng::seed(0x9ac);
    for _ in 0..200 {
        let bits = [2u8, 3, 4][rng.below(3)];
        let mult = match bits {
            2 => 4,
            4 => 2,
            _ => 1,
        };
        let d_in = mult * (1 + rng.below(20));
        let d_out = 1 + rng.below(20);
        let codes: Vec<u8> =
            (0..d_in * d_out).map(|_| rng.below(1 << bits) as u8).collect();
        let p = pack_codes(&codes, d_in, d_out, bits);
        assert_eq!(unpack_codes(&p), codes);
    }
}

/// Quantizers: dequantized output has the same shape, finite values, and
/// error decreases (weakly) with more bits.
#[test]
fn prop_quantizer_error_monotone_in_bits() {
    let mut rng = Rng::seed(0x4b17);
    for _ in 0..30 {
        let d_in = 16 * (1 + rng.below(3));
        let d_out = 8 * (1 + rng.below(3));
        let w = Mat::randn(d_in, d_out, &mut rng);
        let ctx = CalibCtx::with_seed(rng.next_u64());
        let mut last = f32::INFINITY;
        for bits in [2u8, 3, 4] {
            let q = by_name("rtn", bits, 8).unwrap();
            let deq = q.quantize(&w, &ctx).dequant();
            assert_eq!(deq.shape(), w.shape());
            assert!(deq.data().iter().all(|x| x.is_finite()));
            let err = deq.fro_dist(&w);
            assert!(err <= last + 1e-4, "bits={bits}: {err} > {last}");
            last = err;
        }
    }
}

/// Adapter flattening: to_flat/from_flat roundtrip over random dims/ranks.
#[test]
fn prop_adapterset_flat_roundtrip() {
    let mut rng = Rng::seed(0xada);
    for _ in 0..30 {
        let dims = dims_for(&mut rng);
        let rank = 1 + rng.below(8);
        let mut ad = AdapterSet::init_default(&dims, rank, &mut rng, 0.1);
        // randomize B too
        for f in 0..7 {
            for l in 0..dims.n_layers {
                let (a, b) = ad.get(f, l);
                let (a, mut b) = (a.clone(), b.clone());
                b = Mat::randn(b.rows(), rank, &mut rng);
                ad.set(f, l, a, b);
            }
        }
        let ad2 = AdapterSet::from_flat(&dims, rank, &ad.to_flat()).unwrap();
        for f in 0..7 {
            for l in 0..dims.n_layers {
                let (a1, b1) = ad.get(f, l);
                let (a2, b2) = ad2.get(f, l);
                assert!(a1.fro_dist(a2) < 1e-7 && b1.fro_dist(b2) < 1e-7);
            }
        }
    }
}

/// QA-LoRA merge: merging grouped adapters into zero-points reproduces the
/// expanded-adapter dense weights exactly, over random geometry.
#[test]
fn prop_qalora_merge_exact() {
    let mut rng = Rng::seed(0x9a10);
    for _ in 0..30 {
        let dims = dims_for(&mut rng);
        let rank = 1 + rng.below(4);
        let mut g = GroupedAdapterSet::init_default(&dims, rank, &mut rng, 0.2);
        for f in 0..7 {
            for l in 0..dims.n_layers {
                let rows = g.pairs[f][l].1.rows();
                g.pairs[f][l].1 = Mat::randn(rows, rank, &mut rng);
            }
        }
        let teacher = TeacherParams::init(&dims, &mut rng);
        let quant = by_name("rtn", 2, dims.group_size).unwrap();
        let fam = rng.below(7);
        let layer = rng.below(dims.n_layers);
        let w = teacher.linear(fam, layer);
        let qr = quant.quantize(w, &CalibCtx::default());
        let mut q = qr.as_scalar().unwrap().clone();
        let expanded = g.expand(&dims);
        let expected = q.dequant().add(&expanded.delta(fam, layer));
        g.merge_into(fam, layer, &mut q);
        assert!(
            q.dequant().fro_dist(&expected) < 1e-3,
            "merge mismatch: {}",
            q.dequant().fro_dist(&expected)
        );
    }
}

/// Cache keys: fnv64 has no collisions across distinct structured keys of
/// the kind the pipeline generates.
#[test]
fn prop_cache_keys_distinct() {
    let mut keys = std::collections::HashSet::new();
    for cfg in ["tiny", "small", "base"] {
        for q in ["rtn", "nf", "omniquant", "gptq", "quarot", "quip"] {
            for bits in [2, 3, 4] {
                for rank in [4, 8, 16, 32, 64] {
                    for scope in ["linear", "layer", "model", "gt", "model_gt"] {
                        let k = format!("calib:{cfg}:{q}{bits}:scope={scope}:r={rank}");
                        assert!(keys.insert(fnv64(&k)), "collision at {k}");
                    }
                }
            }
        }
    }
}

/// Task generators: every generated item is well-formed and fits the model
/// window, over random seeds.
#[test]
fn prop_tasks_well_formed() {
    let mut rng = Rng::seed(0x7a5c);
    let vocab = Vocab::new(512, 1);
    for _ in 0..10 {
        let seed = rng.next_u64();
        for kind in TaskKind::ALL {
            for it in gen_mc(kind, &vocab, 10, seed) {
                assert!(it.correct < it.choices.len());
                let longest = it.choices.iter().map(Vec::len).max().unwrap();
                assert!(it.prompt.len() + longest <= 128, "item overflows window");
                assert!(it
                    .choices
                    .iter()
                    .all(|c| c.iter().all(|&t| (t as usize) < 512)));
            }
        }
        for it in gen_gsm(&vocab, 10, 2, seed) {
            assert!((4..14).contains(&it.answer));
            assert!(*it.prompt.last().unwrap() == 16); // OP_EQ
        }
    }
}

/// JSON writer/parser: roundtrip over randomly generated JSON values.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f32() < 0.5),
            2 => Json::num((rng.next_f64() * 1e6).round() / 4.0),
            3 => Json::str(format!("s{}-\"q\"\n", rng.below(1000))),
            4 => Json::arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::obj(
                (0..rng.below(4))
                    .map(|i| {
                        let v = gen(rng, depth - 1);
                        (Box::leak(format!("k{i}").into_boxed_str()) as &str, v)
                    })
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::seed(0x150f);
    for _ in 0..100 {
        let j = gen(&mut rng, 3);
        let round = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, round);
        let compact = Json::parse(&j.to_compact()).unwrap();
        assert_eq!(j, compact);
    }
}
