//! Integration tests for the cross-request radix prefix cache
//! (`rilq::engine::prefix`): trie shape under insert / longest-match /
//! node-split, LRU eviction ordering (oldest leaf first, pinned blocks
//! skipped), refcount round-trips through the arena free list, the
//! bitwise pin — prefill over an attached cached prefix produces
//! logits identical (`to_bits`) to a cold prefill on every backend —
//! and the engine-level scheduling contract that index eviction
//! absorbs arena pressure before any decode is preempted.

use std::sync::Arc;

use rilq::engine::{Engine, EngineConfig, PrefixIndex, SamplingParams};
use rilq::eval::{greedy_decode, BackendScorer, Scorer};
use rilq::model::backend::BackendKind;
use rilq::model::{KvArena, ModelDims, StudentWeights, TeacherParams};
use rilq::quant::{by_name, CalibCtx};
use rilq::tensor::Rng;

fn dims() -> ModelDims {
    ModelDims {
        name: "prefix".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 48,
        seq: 16,
        batch: 4,
        group_size: 8,
    }
}

fn backend_scorer(kind: BackendKind, seed: u64) -> Arc<BackendScorer> {
    let d = dims();
    let mut rng = Rng::seed(seed);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    Arc::new(BackendScorer::new(&d, &teacher, &student, None, kind).unwrap())
}

fn packed_scorer(seed: u64) -> Arc<BackendScorer> {
    backend_scorer(BackendKind::Packed, seed)
}

/// Insert, longest-match lookup, and boundary-only node splitting: a
/// second sequence that shares the first two blocks of an existing
/// three-block entry splits its edge at the block boundary (old tail
/// becomes a grandchild), dedupes the shared blocks (the existing path
/// wins), and re-inserting a contained sequence is a pure touch.
#[test]
fn insert_longest_match_and_node_split() {
    let scorer = packed_scorer(90);
    let d = dims();
    let arena = KvArena::new(&d, 4, 6);
    let mut ix = PrefixIndex::new(arena.clone());

    // blocks [0 0 0 0][1 1 1 1][2 2 2 2]
    let s1: Vec<u32> = (0..12).map(|i| (i / 4) as u32).collect();
    // shares the first two blocks, diverges in the third
    let mut s2 = s1[..8].to_vec();
    s2.extend([9, 9, 9, 9]);

    let mut ca = arena.new_cache();
    scorer.cache_forward(&s1, &mut ca).unwrap();
    ix.insert(&s1, &ca);
    assert_eq!(ix.node_count(), 1, "one sequence is one edge");
    assert_eq!(ix.blocks_held(), 3);

    // longest-match is block-granular and respects the caller's limit
    assert_eq!(ix.peek(&s1, 12), 12);
    assert_eq!(ix.peek(&s1, 9), 8, "limit rounds down to whole blocks");
    assert_eq!(ix.peek(&s1, 3), 0, "sub-block limit matches nothing");
    assert_eq!(ix.peek(&s2, 12), 8, "partial edge match is usable");
    assert_eq!(ix.peek(&[7, 7, 7, 7], 4), 0, "unknown first block");

    let mut cb = arena.new_cache();
    scorer.cache_forward(&s2, &mut cb).unwrap();
    ix.insert(&s2, &cb);
    // split at the 2-block boundary: shared edge + two one-block tails
    assert_eq!(ix.node_count(), 3, "split must produce parent + two tails");
    assert_eq!(ix.blocks_held(), 4, "shared blocks dedupe: only the divergent tail is new");
    assert_eq!(ix.peek(&s1, 12), 12);
    assert_eq!(ix.peek(&s2, 12), 12);

    // re-inserting a fully contained sequence changes nothing
    ix.insert(&s1, &ca);
    assert_eq!(ix.node_count(), 3);
    assert_eq!(ix.blocks_held(), 4);

    // the index holds its blocks after every writer cache is gone:
    // s1's three plus s2's divergent tail (its shared prefix blocks
    // were duplicates and were freed with the cache)
    drop(ca);
    drop(cb);
    assert_eq!(arena.blocks_in_use(), 4);
    drop(ix);
    assert_eq!(arena.blocks_in_use(), 0, "dropping the index must release every block");
}

/// LRU eviction takes the least-recently-used leaf first and never
/// frees a block an attached cache still pins (arena refcount > 1);
/// once the last outside holder releases, the same entry becomes
/// evictable.
#[test]
fn evict_lru_prefers_oldest_and_skips_pinned() {
    let scorer = packed_scorer(91);
    let d = dims();
    let arena = KvArena::new(&d, 4, 6);
    let mut ix = PrefixIndex::new(arena.clone());

    let s1: Vec<u32> = vec![1; 8];
    let s2: Vec<u32> = vec![2; 8];
    let mut ca = arena.new_cache();
    scorer.cache_forward(&s1, &mut ca).unwrap();
    ix.insert(&s1, &ca);
    let mut cb = arena.new_cache();
    scorer.cache_forward(&s2, &mut cb).unwrap();
    ix.insert(&s2, &cb);
    drop(ca);
    drop(cb);
    assert_eq!(ix.blocks_held(), 4);
    assert_eq!(arena.blocks_in_use(), 4);

    // attaching s1 refreshes its recency AND pins its blocks
    let mut live = arena.new_cache();
    assert_eq!(ix.attach(&s1, 8, &mut live), 8);
    assert_eq!(live.len(), 8);

    // under pressure the stale s2 leaf goes first — whole leaf, even
    // though only one block was asked for
    assert_eq!(ix.evict_lru(1), 2, "LRU leaf is released in full");
    assert_eq!(ix.blocks_held(), 2);
    assert_eq!(ix.peek(&s2, 8), 0, "evicted entry no longer matches");
    assert_eq!(ix.peek(&s1, 8), 8, "recently attached entry survives");
    assert_eq!(arena.blocks_in_use(), 2);

    // everything left is pinned by the live cache: eviction frees nothing
    assert_eq!(ix.evict_lru(10), 0, "pinned blocks must never be evicted");
    assert_eq!(ix.blocks_held(), 2);
    assert_eq!(ix.peek(&s1, 8), 8);

    // the outside holder releases; the entry is evictable again
    drop(live);
    assert_eq!(ix.evict_lru(10), 2);
    assert_eq!(ix.blocks_held(), 0);
    assert_eq!(arena.blocks_in_use(), 0);
}

/// Refcount round-trip through the free list: index-held blocks are
/// real residency (a newcomer cannot over-reserve past them), and an
/// evicted block is recycled — not re-created — for the next writer.
#[test]
fn freed_shared_blocks_recycle_only_after_last_release() {
    let scorer = packed_scorer(92);
    let d = dims();
    let arena = KvArena::new(&d, 4, 2); // exactly two blocks
    let mut ix = PrefixIndex::new(arena.clone());

    let s: Vec<u32> = vec![7; 8];
    let mut ca = arena.new_cache();
    scorer.cache_forward(&s, &mut ca).unwrap();
    ix.insert(&s, &ca);
    drop(ca);
    assert_eq!(arena.blocks_in_use(), 2, "the index keeps the blocks resident");
    let created = arena.blocks_created();

    let mut c = arena.new_cache();
    assert!(c.reserve(4).is_err(), "index-held blocks are not free capacity");

    assert_eq!(ix.evict_lru(2), 2);
    c.reserve(8).unwrap();
    assert_eq!(arena.blocks_in_use(), 2);
    assert_eq!(arena.blocks_created(), created, "freed blocks recycle, never re-allocate");
}

/// The bitwise pin behind all cross-request reuse: prefilling only the
/// suffix over an attached cached prefix yields logits bitwise
/// identical to a cold full-prompt prefill — on every backend, and
/// whether the suffix is fed in one shot or chunked.
#[test]
fn cached_prefix_prefill_is_bitwise_identical_across_backends() {
    for kind in BackendKind::ALL {
        let scorer = backend_scorer(kind, 93);
        let d = dims();
        let arena = KvArena::new(&d, 4, 8);
        let mut ix = PrefixIndex::new(arena.clone());
        let mut rng = Rng::seed(94);
        let prompt_a: Vec<u32> = (0..10).map(|_| rng.below(d.vocab) as u32).collect();
        let mut prompt_b = prompt_a[..8].to_vec();
        prompt_b.extend((0..4).map(|_| rng.below(d.vocab) as u32));

        // publish prompt_a's whole blocks (10 tokens -> 2 of 4-pos blocks)
        let mut ca = arena.new_cache();
        scorer.cache_forward(&prompt_a, &mut ca).unwrap();
        ix.insert(&prompt_a, &ca);
        drop(ca);
        assert_eq!(arena.blocks_in_use(), 2, "[{kind:?}] only whole blocks are published");

        // cold baseline: full prefill of prompt_b in a fresh cache
        let mut cc = arena.new_cache();
        let lg_cold = scorer.cache_forward(&prompt_b, &mut cc).unwrap();
        assert_eq!(lg_cold.rows(), 12);

        // warm: attach the shared 8-token prefix, forward only the suffix
        let mut cw = arena.new_cache();
        assert_eq!(ix.attach(&prompt_b, prompt_b.len(), &mut cw), 8, "[{kind:?}]");
        let lg_warm = scorer.cache_forward(&prompt_b[8..], &mut cw).unwrap();
        assert_eq!(lg_warm.rows(), 4);
        for i in 0..4 {
            for (a, b) in lg_warm.row(i).iter().zip(lg_cold.row(8 + i)) {
                assert_eq!(a.to_bits(), b.to_bits(), "[{kind:?}] warm suffix row {i} drifted");
            }
        }
        assert_eq!(cw.len(), cc.len());

        // chunked warm prefill (the engine feeds suffixes in chunks)
        let mut cw2 = arena.new_cache();
        assert_eq!(ix.attach(&prompt_b, prompt_b.len(), &mut cw2), 8);
        let lg_c1 = scorer.cache_forward(&prompt_b[8..10], &mut cw2).unwrap();
        let lg_c2 = scorer.cache_forward(&prompt_b[10..12], &mut cw2).unwrap();
        for i in 0..2 {
            for (a, b) in lg_c1.row(i).iter().zip(lg_cold.row(8 + i)) {
                assert_eq!(a.to_bits(), b.to_bits(), "[{kind:?}] chunk 1 row {i} drifted");
            }
            for (a, b) in lg_c2.row(i).iter().zip(lg_cold.row(10 + i)) {
                assert_eq!(a.to_bits(), b.to_bits(), "[{kind:?}] chunk 2 row {i} drifted");
            }
        }

        drop(cc);
        drop(cw);
        drop(cw2);
        drop(ix);
        assert_eq!(arena.blocks_in_use(), 0, "[{kind:?}] blocks leaked");
    }
}

/// Eviction-under-pressure ordering at the engine level: a finished
/// generation leaves its prefix resident in the index; when later cold
/// decodes need those blocks back, the scheduler reclaims them from
/// the index (`serve.prefix_evictions`) instead of preempting a live
/// decode — and the outputs stay bitwise greedy.
#[test]
fn trie_eviction_fires_before_preemption_under_pressure() {
    let scorer = packed_scorer(95);
    let warm_prompt: Vec<u32> = vec![5; 8];
    let cold_a: Vec<u32> = vec![6; 8];
    let cold_b: Vec<u32> = vec![7; 8];
    let max_new = 5;
    let want_warm = greedy_decode(scorer.as_ref(), &warm_prompt, 1).unwrap();
    let want_a = greedy_decode(scorer.as_ref(), &cold_a, max_new).unwrap();
    let want_b = greedy_decode(scorer.as_ref(), &cold_b, max_new).unwrap();

    let engine = Engine::start_shared(
        scorer.clone(),
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 2,
            prefill_chunk: 4,
            kv_block: 4,
            // warm prefix (2) + two live decodes (3 each) overflow by 2:
            // exactly the index's share
            arena_blocks: 6,
            ..EngineConfig::default()
        },
    );
    let arena = engine.arenas()[0].clone();
    let client = engine.client();

    // the warm generation finishes and its 8-token prefix (2 whole
    // blocks) stays resident in the index
    let warm =
        client.generate(warm_prompt.clone(), SamplingParams::greedy(1)).unwrap().wait().unwrap();
    assert_eq!(warm.tokens, want_warm.0);
    assert_eq!(arena.blocks_in_use(), 2, "finished prefix should stay index-resident");

    // two cold generations need 3 blocks each by their final step: the
    // index must give its 2 blocks back, and nobody gets preempted
    let pa = client.generate(cold_a.clone(), SamplingParams::greedy(max_new)).unwrap();
    let pb = client.generate(cold_b.clone(), SamplingParams::greedy(max_new)).unwrap();
    let ga = pa.wait().unwrap();
    let gb = pb.wait().unwrap();
    assert_eq!(ga.tokens, want_a.0);
    assert_eq!(gb.tokens, want_b.0);
    for (got, want) in [(&ga, &want_a), (&gb, &want_b)] {
        for (x, y) in got.logps.iter().zip(&want.1) {
            assert_eq!(x.to_bits(), y.to_bits(), "cold decode logps drifted from greedy");
        }
    }

    drop(client);
    let summary = engine.shutdown();
    assert!(
        summary.prefix_evictions >= 1.0,
        "the index never released blocks under pressure: {summary}"
    );
    assert_eq!(summary.preemptions, 0.0, "index LRU must absorb pressure before preemption");
    assert_eq!(summary.errors, 0.0);
    assert_eq!(summary.kv_blocks_pinned, 0.0, "index pins survived shutdown");
    assert_eq!(arena.blocks_in_use(), 0, "arena blocks leaked through shutdown");
}
