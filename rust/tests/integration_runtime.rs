//! Integration tests over the real AOT artifacts: the PJRT-executed HLO
//! must agree with the pure-Rust reference model, and the train-step
//! artifacts must actually optimize their losses.
//!
//! These tests need `make artifacts` to have run; they skip (with a note)
//! when `artifacts/manifest.json` is absent so `cargo test` stays green on
//! a fresh checkout.

use rilq::lqec::AdapterSet;
use rilq::model::forward::{forward_trace, token_logp};
use rilq::model::{ModelDims, StudentWeights, TeacherParams};
use rilq::quant::{CalibCtx, Quantizer, Rtn};
use rilq::runtime::bindings::{output_f32, output_scalar, Bindings};
use rilq::runtime::Runtime;
use rilq::tensor::Rng;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping integration test: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

fn random_batch(dims: &ModelDims, rng: &mut Rng) -> Vec<Vec<u32>> {
    (0..dims.batch)
        .map(|_| (0..dims.seq).map(|_| rng.below(dims.vocab) as u32).collect())
        .collect()
}

#[test]
fn teacher_fwd_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let dims = rt.manifest.dims("tiny").unwrap().clone();
    let mut rng = Rng::seed(2001);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let batch = random_batch(&dims, &mut rng);

    let spec = rt.manifest.artifact("teacher_fwd_tiny").unwrap().clone();
    let mut b = Bindings::new();
    b.teacher(&teacher).tokens(&batch, &dims);
    let outs = rt.run("teacher_fwd_tiny", &b.to_literals(&spec).unwrap()).unwrap();
    let logp = output_f32(&spec, &outs, "logp").unwrap();
    assert_eq!(logp.len(), dims.batch * (dims.seq - 1));

    // cross-check every sequence against the pure-Rust reference
    let view = teacher.view();
    for (i, seq) in batch.iter().enumerate() {
        let trace = forward_trace(&dims, &view, seq);
        let ref_logp = token_logp(&trace.logits, seq);
        let hlo_logp = &logp[i * (dims.seq - 1)..(i + 1) * (dims.seq - 1)];
        for (pos, (&a, &b)) in ref_logp.iter().zip(hlo_logp).enumerate() {
            assert!(
                (a - b).abs() < 2e-2 * (1.0 + a.abs()),
                "seq {i} pos {pos}: rust {a} vs hlo {b}"
            );
        }
    }
}

#[test]
fn student_fwd_matches_rust_reference_with_adapters() {
    let Some(rt) = runtime() else { return };
    let dims = rt.manifest.dims("tiny").unwrap().clone();
    let mut rng = Rng::seed(2002);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student =
        StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    // non-trivial adapters on both sides
    let mut adapters = AdapterSet::init_default(&dims, 4, &mut rng, 0.02);
    for f in 0..7 {
        for l in 0..dims.n_layers {
            let (a, _) = adapters.get(f, l);
            let a = a.clone();
            let (_, dout) = dims.linear_dims(rilq::model::LINEARS[f]);
            let b = rilq::tensor::Mat::randn(dout, 4, &mut rng).scale(0.02);
            adapters.set(f, l, a, b);
        }
    }
    let batch = random_batch(&dims, &mut rng);

    let name = "student_fwd_tiny_r4";
    let spec = rt.manifest.artifact(name).unwrap().clone();
    let mut b = Bindings::new();
    b.teacher(&teacher)
        .qweights(&student)
        .adapters("ad.", &adapters.to_flat())
        .tokens(&batch, &dims);
    let outs = rt.run(name, &b.to_literals(&spec).unwrap()).unwrap();
    let logp = output_f32(&spec, &outs, "logp").unwrap();

    // rust reference with merged effective weights
    let dense = rilq::model::forward::effective_weights(&student, Some(&adapters));
    let view = teacher.view_with(&dense);
    for (i, seq) in batch.iter().enumerate() {
        let trace = forward_trace(&dims, &view, seq);
        let ref_logp = token_logp(&trace.logits, seq);
        let hlo_logp = &logp[i * (dims.seq - 1)..(i + 1) * (dims.seq - 1)];
        for (pos, (&a, &bb)) in ref_logp.iter().zip(hlo_logp).enumerate() {
            assert!(
                (a - bb).abs() < 2e-2 * (1.0 + a.abs()),
                "seq {i} pos {pos}: rust {a} vs hlo {bb}"
            );
        }
    }
}

#[test]
fn packed_student_fwd_matches_dense_student_fwd() {
    let Some(rt) = runtime() else { return };
    let dims = rt.manifest.dims("tiny").unwrap().clone();
    let mut rng = Rng::seed(2003);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student =
        StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    let adapters = AdapterSet::init_default(&dims, 4, &mut rng, 0.02);
    let batch = random_batch(&dims, &mut rng);

    // dense path
    let dname = "student_fwd_tiny_r4";
    let dspec = rt.manifest.artifact(dname).unwrap().clone();
    let mut b = Bindings::new();
    b.teacher(&teacher)
        .qweights(&student)
        .adapters("ad.", &adapters.to_flat())
        .tokens(&batch, &dims);
    let douts = rt.run(dname, &b.to_literals(&dspec).unwrap()).unwrap();
    let dense_logp = output_f32(&dspec, &douts, "logp").unwrap();

    // packed path: RTN is a scalar-codebook quantizer, so codes/scales/zeros
    // feed the fused Pallas dequant kernel directly
    let pname = "student_fwd_packed_tiny_r4_w2";
    let pspec = rt.manifest.artifact(pname).unwrap().clone();
    let mut packed = Vec::new();
    let mut scales = Vec::new();
    let mut zeros = Vec::new();
    let mut codebook = Vec::new();
    for f in 0..7 {
        let mut fam_packed = Vec::new();
        let mut fam_scales = Vec::new();
        let mut fam_zeros = Vec::new();
        for l in 0..dims.n_layers {
            let q = student.q[f][l].as_scalar().expect("rtn is scalar");
            fam_packed.push(q.pack());
            fam_scales.extend_from_slice(q.scales.data());
            fam_zeros.extend_from_slice(q.zeros.data());
            codebook = q.codebook.clone();
        }
        packed.push(fam_packed);
        scales.push(fam_scales);
        zeros.push(fam_zeros);
    }
    let mut b = Bindings::new();
    b.teacher(&teacher)
        .packed(&packed, &scales, &zeros, &codebook)
        .adapters("ad.", &adapters.to_flat())
        .tokens(&batch, &dims);
    let pouts = rt.run(pname, &b.to_literals(&pspec).unwrap()).unwrap();
    let packed_logp = output_f32(&pspec, &pouts, "logp").unwrap();

    assert_eq!(dense_logp.len(), packed_logp.len());
    for (i, (&a, &b)) in dense_logp.iter().zip(&packed_logp).enumerate() {
        assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "pos {i}: dense {a} vs packed {b}");
    }
}

#[test]
fn train_step_decreases_model_loss() {
    let Some(rt) = runtime() else { return };
    let dims = rt.manifest.dims("tiny").unwrap().clone();
    let mut rng = Rng::seed(2004);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student =
        StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    let adapters = AdapterSet::init_default(&dims, 4, &mut rng, 0.01);
    let batch = random_batch(&dims, &mut rng);

    let name = "train_step_tiny_r4_model";
    let spec = rt.manifest.artifact(name).unwrap().clone();
    let mut ad_flat = adapters.to_flat();
    let mut m_flat = adapters.zeros_like_flat();
    let mut v_flat = adapters.zeros_like_flat();

    let mut losses = Vec::new();
    for step in 0..8 {
        let mut b = Bindings::new();
        b.teacher(&teacher)
            .qweights(&student)
            .adapters("ad.", &ad_flat)
            .adapters("m.", &m_flat)
            .adapters("v.", &v_flat)
            .step_lr((step + 1) as f32, 3e-3)
            .tokens(&batch, &dims);
        let outs = rt.run(name, &b.to_literals(&spec).unwrap()).unwrap();
        losses.push(output_scalar(&spec, &outs, "loss").unwrap());
        ad_flat = rilq::runtime::bindings::output_adapter_flat(&spec, &outs, "ad.").unwrap();
        m_flat = rilq::runtime::bindings::output_adapter_flat(&spec, &outs, "m.").unwrap();
        v_flat = rilq::runtime::bindings::output_adapter_flat(&spec, &outs, "v.").unwrap();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
    // Model-Loss on a quantized model starts well above zero
    assert!(losses[0] > 1e-3, "suspiciously small initial loss {losses:?}");
}

#[test]
fn probe_artifact_reports_relative_errors() {
    let Some(rt) = runtime() else { return };
    let dims = rt.manifest.dims("tiny").unwrap().clone();
    let mut rng = Rng::seed(2005);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student =
        StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    let adapters = AdapterSet::zeros(&dims, 4);
    let batch = random_batch(&dims, &mut rng);

    let name = "probe_tiny_r4";
    let spec = rt.manifest.artifact(name).unwrap().clone();
    let mut b = Bindings::new();
    b.teacher(&teacher)
        .qweights(&student)
        .adapters("ad.", &adapters.to_flat())
        .tokens(&batch, &dims);
    let outs = rt.run(name, &b.to_literals(&spec).unwrap()).unwrap();
    let layer_rel = output_f32(&spec, &outs, "layer_rel").unwrap();
    let head_rel = output_scalar(&spec, &outs, "head_rel").unwrap();
    assert_eq!(layer_rel.len(), dims.n_layers);
    assert!(layer_rel.iter().all(|&x| x > 0.0 && x.is_finite()));
    assert!(head_rel > 0.0 && head_rel.is_finite());
    // 2-bit quantization without compensation: visible degradation
    assert!(head_rel > 0.01, "head_rel={head_rel}");
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.artifact("teacher_fwd_tiny").unwrap().clone();
    // wrong number of inputs
    let err = rt.run("teacher_fwd_tiny", &[]);
    assert!(err.is_err());
    let _ = spec;
}

#[test]
fn rust_forward_matches_jax_golden_vector() {
    let path = std::path::Path::new("artifacts/testvec_tiny.bin");
    if !path.exists() {
        eprintln!("skipping: golden vector not built");
        return;
    }
    use rilq::model::weights::TensorFile;
    use rilq::tensor::Mat;
    let tf = TensorFile::load(path).unwrap();
    let dims = ModelDims {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        d_ff: 192,
        vocab: 256,
        seq: 64,
        batch: 8,
        group_size: 32,
    };
    let get = |n: &str| tf.get(n).unwrap().1.clone();
    let flat = vec![
        get("embed"), get("wq"), get("wk"), get("wv"), get("wo"),
        get("wg"), get("wu"), get("wd"), get("ln1"), get("ln2"),
        get("fnorm"), get("head"),
    ];
    let teacher = TeacherParams::from_flat(&dims, &flat).unwrap();
    let tokens: Vec<u32> = get("tokens").iter().map(|&x| x as u32).collect();
    let trace = forward_trace(&dims, &teacher.view(), &tokens);
    let golden = get("logits");
    let golden = Mat::from_vec(dims.seq, dims.vocab, golden);
    let dist = trace.logits.fro_dist(&golden);
    let rel = dist / golden.fro_norm();
    assert!(rel < 1e-3, "rel={rel}; logits[3][0] rust={} jax={}",
        trace.logits[(3, 0)], golden[(3, 0)]);
}
