//! Parity tests for the `LinearBackend` execution engines: the fused
//! packed+LoRA serving form must match the dequantize-then-dense-matmul
//! oracle at the single-linear level (all scalar quantizers, all packed
//! bit widths, odd shapes) and at the full-model-logits level, and its
//! resident weight memory must be a fraction of dense f32. These tests
//! are PJRT-free — they exercise the native engine only.

use rilq::eval::{BackendScorer, Scorer};
use rilq::lqec::AdapterSet;
use rilq::model::backend::{student_backends, BackendKind, LinearBackend, PackedLoraLinear};
use rilq::model::forward::{forward_trace, forward_trace_batch};
use rilq::model::{ModelDims, StudentWeights, TeacherParams, LINEARS};
use rilq::quant::{by_name, CalibCtx, Quantizer};
use rilq::tensor::{Mat, Rng};

fn dims(d_model: usize, d_ff: usize, group_size: usize) -> ModelDims {
    ModelDims {
        name: "parity".into(),
        d_model,
        n_layers: 2,
        n_heads: 2,
        d_ff,
        vocab: 48,
        seq: 16,
        batch: 2,
        group_size,
    }
}

/// Packed forward vs `x · dequant(Q)` for every scalar quantizer, bits in
/// {2, 3, 4}, including odd shapes: `d_in` not divisible by the group
/// size and not divisible by the codes-per-byte packing factor.
#[test]
fn packed_linear_matches_dequant_dense_all_quantizers() {
    let mut rng = Rng::seed(9001);
    let shapes = [(32usize, 12usize, 8usize), (48, 16, 16), (40, 10, 16), (37, 9, 16)];
    for name in ["rtn", "nf", "omniquant", "gptq"] {
        for bits in [2u8, 3, 4] {
            for &(d_in, d_out, gs) in &shapes {
                let q = by_name(name, bits, gs).unwrap();
                let w = Mat::randn(d_in, d_out, &mut rng);
                let qr = q.quantize(&w, &CalibCtx::with_seed(7));
                let scalar = qr
                    .as_scalar()
                    .unwrap_or_else(|| panic!("{name} should produce scalar codes"));
                let x = Mat::randn(6, d_in, &mut rng);
                let oracle = x.matmul(&scalar.dequant());
                let packed = PackedLoraLinear::from_quantized(scalar, None).forward(&x);
                let err = oracle.fro_dist(&packed);
                let tol = 1e-4 * oracle.fro_norm().max(1.0);
                assert!(
                    err <= tol,
                    "{name} bits={bits} d_in={d_in} d_out={d_out} gs={gs}: err={err} tol={tol}"
                );
            }
        }
    }
}

/// The rank-r correction: packed + unmerged LoRA must match the
/// adapter-merged dense oracle.
#[test]
fn packed_lora_matches_merged_oracle() {
    let mut rng = Rng::seed(9002);
    for (d_in, d_out, gs, r) in [(32usize, 12usize, 8usize, 4usize), (37, 9, 16, 3)] {
        let q = by_name("rtn", 2, gs).unwrap();
        let w = Mat::randn(d_in, d_out, &mut rng);
        let scalar = q.quantize(&w, &CalibCtx::default());
        let scalar = scalar.as_scalar().unwrap();
        let a = Mat::randn(d_in, r, &mut rng).scale(0.1);
        let b = Mat::randn(d_out, r, &mut rng).scale(0.1);
        let x = Mat::randn(5, d_in, &mut rng);
        let merged = x.matmul(&scalar.dequant().add(&a.matmul_t(&b)));
        let packed =
            PackedLoraLinear::from_quantized(scalar, Some((a, b))).forward(&x);
        let err = merged.fro_dist(&packed);
        let tol = 1e-4 * merged.fro_norm().max(1.0);
        assert!(err <= tol, "d_in={d_in} gs={gs}: err={err} tol={tol}");
    }
}

fn nonzero_adapters(d: &ModelDims, rank: usize, rng: &mut Rng) -> AdapterSet {
    let mut ad = AdapterSet::zeros(d, rank);
    for f in 0..7 {
        for l in 0..d.n_layers {
            let (di, do_) = d.linear_dims(LINEARS[f]);
            ad.set(
                f,
                l,
                Mat::randn(di, rank, rng).scale(0.05),
                Mat::randn(do_, rank, rng).scale(0.05),
            );
        }
    }
    ad
}

/// Acceptance: full-model forward logits through the packed engine match
/// the dense-dequant path within 1e-3.
#[test]
fn full_model_logits_parity_across_backends() {
    let d = dims(16, 32, 8);
    let mut rng = Rng::seed(9003);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    let adapters = nonzero_adapters(&d, 4, &mut rng);
    let tokens: Vec<u32> = (0..12).map(|_| rng.below(d.vocab) as u32).collect();

    let engines: Vec<_> = BackendKind::ALL
        .iter()
        .map(|&k| student_backends(&student, Some(&adapters), k).unwrap())
        .collect();
    let logits: Vec<Mat> = engines
        .iter()
        .map(|e| forward_trace(&d, &teacher.view_backends(e), &tokens).logits)
        .collect();
    for (i, l) in logits.iter().enumerate().skip(1) {
        let mut max_abs = 0.0f32;
        for r in 0..l.rows() {
            for c in 0..l.cols() {
                max_abs = max_abs.max((l[(r, c)] - logits[0][(r, c)]).abs());
            }
        }
        assert!(
            max_abs < 1e-3,
            "backend {} vs dense: max logit diff {max_abs}",
            BackendKind::ALL[i]
        );
    }
}

/// Acceptance: the batched multi-sequence forward must reproduce the
/// per-sequence forward's logits to <= 1e-5 for every backend, over a
/// ragged batch (the serving path's coalesced geometry).
#[test]
fn batched_forward_matches_per_sequence_all_backends() {
    let d = dims(16, 32, 8);
    let mut rng = Rng::seed(9009);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    let adapters = nonzero_adapters(&d, 4, &mut rng);
    let lens = [16usize, 5, 1, 9, 12];
    let seqs: Vec<Vec<u32>> = lens
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    for kind in BackendKind::ALL {
        let engines = student_backends(&student, Some(&adapters), kind).unwrap();
        let view = teacher.view_backends(&engines);
        let batched = forward_trace_batch(&d, &view, &seqs);
        assert_eq!(batched.len(), seqs.len());
        for (seq, lg) in seqs.iter().zip(&batched) {
            let solo = forward_trace(&d, &view, seq).logits;
            let mut max_abs = 0.0f32;
            for r in 0..solo.rows() {
                for c in 0..solo.cols() {
                    max_abs = max_abs.max((solo[(r, c)] - lg[(r, c)]).abs());
                }
            }
            assert!(
                max_abs <= 1e-5,
                "backend {kind}, len {}: batched vs per-sequence max diff {max_abs}",
                seq.len()
            );
        }
    }
}

/// The scorer-level view of the same parity: per-token log-probs agree
/// across all three engines.
#[test]
fn backend_scorers_agree_on_logp() {
    let d = dims(16, 32, 8);
    let mut rng = Rng::seed(9004);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("nf", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    let adapters = nonzero_adapters(&d, 4, &mut rng);
    let seqs: Vec<Vec<u32>> = (0..3)
        .map(|_| (0..d.seq).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let scored: Vec<Vec<Vec<f32>>> = BackendKind::ALL
        .iter()
        .map(|&k| {
            BackendScorer::new(&d, &teacher, &student, Some(&adapters), k)
                .unwrap()
                .score_all(&seqs)
                .unwrap()
        })
        .collect();
    for k in 1..scored.len() {
        for (a, b) in scored[0].iter().flatten().zip(scored[k].iter().flatten()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b} (backend {})", BackendKind::ALL[k]);
        }
    }
}

/// Acceptance: at 2-bit the packed engine's resident weight memory is
/// under 1/4 of the dense f32 engine across the whole model.
#[test]
fn packed_weight_memory_under_quarter_of_dense() {
    let d = dims(64, 128, 32);
    let mut rng = Rng::seed(9005);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    let packed = BackendScorer::new(&d, &teacher, &student, None, BackendKind::Packed).unwrap();
    let dense = BackendScorer::new(&d, &teacher, &student, None, BackendKind::Dense).unwrap();
    assert!(
        packed.weight_bytes() * 4 < dense.weight_bytes(),
        "packed={} dense={}",
        packed.weight_bytes(),
        dense.weight_bytes()
    );
}

/// Rotation/VQ quantizers carry no scalar codes: the packed engine must
/// refuse them with a clear error while dense still works.
#[test]
fn packed_rejects_non_scalar_quantizers() {
    let d = dims(16, 32, 8);
    let mut rng = Rng::seed(9006);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("vq", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::with_seed(3)
    });
    let err = student_backends(&student, None, BackendKind::Packed)
        .err()
        .expect("packed must reject VQ students");
    assert!(format!("{err}").contains("scalar"), "{err}");
    assert!(student_backends(&student, None, BackendKind::Dense).is_ok());
}

/// Zero adapters (the "no LQEC" baseline) must be a no-op in every engine:
/// same logits as no adapters at all.
#[test]
fn zero_adapters_are_noop() {
    let d = dims(16, 32, 8);
    let mut rng = Rng::seed(9007);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    let zeros = AdapterSet::zeros(&d, 4);
    let tokens: Vec<u32> = (0..10).map(|_| rng.below(d.vocab) as u32).collect();
    for kind in BackendKind::ALL {
        let with = student_backends(&student, Some(&zeros), kind).unwrap();
        let without = student_backends(&student, None, kind).unwrap();
        let a = forward_trace(&d, &teacher.view_backends(&with), &tokens).logits;
        let b = forward_trace(&d, &teacher.view_backends(&without), &tokens).logits;
        assert!(a.fro_dist(&b) < 1e-6, "backend {kind}");
    }
}

/// The engine weight accounting must track the quantized-tensor storage
/// accounting (codes + metadata) for the packed form.
#[test]
fn packed_weight_bytes_match_storage_accounting() {
    let d = dims(64, 128, 32);
    let mut rng = Rng::seed(9008);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    let engines = student_backends(&student, None, BackendKind::Packed).unwrap();
    let engine_bytes: usize = engines.iter().flatten().map(|b| b.weight_bytes()).sum();
    // same order of magnitude as QuantResult::storage_bytes (which counts
    // fractional code bits rather than whole packed bytes)
    let storage = student.storage_bytes();
    assert!(
        engine_bytes >= storage && engine_bytes < storage + storage / 2,
        "engine={engine_bytes} storage={storage}"
    );
}
