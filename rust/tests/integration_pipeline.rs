//! Full-pipeline integration tests over the tiny config: pretrain a few
//! steps, quantize, compensate, evaluate — the end-to-end path every
//! experiment uses. Skips when artifacts are missing.

use rilq::coordinator::driver::{CalibConfig, Driver, PretrainConfig};
use rilq::data::Profile;
use rilq::eval::Scorer;
use rilq::experiments::pipeline::Lab;
use rilq::model::TeacherParams;
use rilq::runtime::Runtime;
use rilq::tensor::Rng;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

#[test]
fn pretrain_reduces_loss_tiny() {
    let Some(rt) = runtime() else { return };
    let dims = rt.manifest.dims("tiny").unwrap().clone();
    let mut rng = Rng::seed(3001);
    let init = TeacherParams::init(&dims, &mut rng);
    let cfg = PretrainConfig {
        steps: 40,
        lr: 3e-3,
        warmup: 5,
        seed: 7,
        profile: Profile::WikiSim,
        log_every: 0,
    };
    let (_trained, losses) = Driver::new(&rt).pretrain(&dims, &init, &cfg).unwrap();
    assert_eq!(losses.len(), 40);
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[35..].iter().sum::<f32>() / 5.0;
    assert!(
        tail < head * 0.8,
        "pretraining did not learn: head={head} tail={tail}"
    );
}

#[test]
fn full_compensation_pipeline_tiny() {
    let Some(rt) = runtime() else { return };
    let mut lab = Lab::new(&rt);
    lab.pretrain_steps_override = Some(80);
    lab.calib = CalibConfig {
        max_steps: 30,
        lr: 2e-3,
        patience: 50,
        min_delta: 1e-6,
        n_samples: 32,
        seed: 5,
        profile: Profile::C4Sim,
    };
    // fresh cache dir per run to keep the test hermetic
    let tmp = std::env::temp_dir().join(format!("rilq_lab_{}", std::process::id()));
    lab.cache = rilq::coordinator::RunCache::new(&tmp);

    let (dims, teacher, pre_losses) = lab.teacher("tiny").unwrap();
    assert!(!pre_losses.is_empty());

    // quantize at 2-bit: quality craters
    let student = lab.quantize(&dims, &teacher, "rtn", 2).unwrap();
    let t_scorer = lab.teacher_scorer(&dims, &teacher).unwrap();
    let base_eval = lab.evaluate(&t_scorer, &dims).unwrap();

    let zeros = rilq::lqec::AdapterSet::zeros(&dims, 4);
    let q_scorer = lab.student_scorer(&dims, &teacher, &student, &zeros).unwrap();
    let q_eval = lab.evaluate(&q_scorer, &dims).unwrap();
    assert!(
        q_eval.ppl_wiki > base_eval.ppl_wiki * 1.05,
        "2-bit should hurt ppl: fp={} q={}",
        base_eval.ppl_wiki,
        q_eval.ppl_wiki
    );

    // RILQ compensation recovers part of the gap
    let init = lab.default_adapters(&dims, 4);
    let (adapters, res) = lab
        .compensate(&dims, &teacher, &student, &init, "model_gt", "rtn2")
        .unwrap();
    // compare epoch-averaged loss (per-step losses are noisy across the
    // cycling calibration batches)
    let n = res.losses.len();
    let head: f32 = res.losses[..4].iter().sum::<f32>() / 4.0;
    let tail: f32 = res.losses[n - 4..].iter().sum::<f32>() / 4.0;
    assert!(tail < head, "calibration loss did not improve: {head} -> {tail}");
    let r_scorer = lab.student_scorer(&dims, &teacher, &student, &adapters).unwrap();
    let r_eval = lab.evaluate(&r_scorer, &dims).unwrap();
    assert!(
        r_eval.ppl_wiki < q_eval.ppl_wiki,
        "RILQ should improve ppl: q={} rilq={}",
        q_eval.ppl_wiki,
        r_eval.ppl_wiki
    );

    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn scorer_consistency_hlo_vs_native() {
    let Some(rt) = runtime() else { return };
    let lab = Lab::new(&rt);
    let dims = lab.dims("tiny").unwrap();
    let mut rng = Rng::seed(3003);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let hlo = lab.teacher_scorer(&dims, &teacher).unwrap();
    let native = rilq::eval::NativeScorer {
        dims: dims.clone(),
        teacher: teacher.clone(),
        dense: None,
    };
    let seqs: Vec<Vec<u32>> = (0..3)
        .map(|_| (0..dims.seq).map(|_| rng.below(dims.vocab) as u32).collect())
        .collect();
    let a = hlo.score_all(&seqs).unwrap();
    let b = native.score_all(&seqs).unwrap();
    for (x, y) in a.iter().flatten().zip(b.iter().flatten()) {
        assert!((x - y).abs() < 2e-2 * (1.0 + x.abs()), "{x} vs {y}");
    }
}

#[test]
fn dbg_execute_b_minimal() {
    let Some(rt) = runtime() else { return };
    let dims = rt.manifest.dims("tiny").unwrap().clone();
    let mut rng = Rng::seed(4001);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let spec = rt.manifest.artifact("teacher_fwd_tiny").unwrap().clone();
    let mut b = rilq::runtime::Bindings::new();
    let batch: Vec<Vec<u32>> = (0..dims.batch)
        .map(|_| (0..dims.seq).map(|_| rng.below(dims.vocab) as u32).collect())
        .collect();
    b.teacher(&teacher).tokens(&batch, &dims);
    // literal path (known good)
    let lits = b.to_literals(&spec).unwrap();
    let outs1 = rt.run("teacher_fwd_tiny", &lits).unwrap();
    let lp1 = rilq::runtime::bindings::output_f32(&spec, &outs1, "logp").unwrap();
    eprintln!("literal path ok, lp1[0]={}", lp1[0]);
    // buffer path: upload each literal
    let bufs: Vec<xla::PjRtBuffer> = lits
        .iter()
        .map(|l| rt.buffer_from_literal(l).unwrap())
        .collect();
    eprintln!("uploaded {} buffers", bufs.len());
    let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let outs2 = rt.run_b("teacher_fwd_tiny", &refs).unwrap();
    let lp2 = rilq::runtime::bindings::output_f32(&spec, &outs2, "logp").unwrap();
    eprintln!("buffer path ok, lp2[0]={}", lp2[0]);
    assert!((lp1[0] - lp2[0]).abs() < 1e-5);
    // REUSE the same buffers for a second execute — donation check
    let outs3 = rt.run_b("teacher_fwd_tiny", &refs).unwrap();
    let lp3 = rilq::runtime::bindings::output_f32(&spec, &outs3, "logp").unwrap();
    eprintln!("buffer REUSE ok, lp3[0]={}", lp3[0]);
    assert!((lp1[0] - lp3[0]).abs() < 1e-5);
}
