//! Integration tests for the typed engine API: greedy engine generation
//! is bitwise-identical to `greedy_decode` across dense/packed/merged
//! backends, seeded sampling replays deterministically, stop tokens
//! truncate, degenerate budgets behave, streamed tokens equal the
//! collected answer, and `Choices` requests match direct choice scoring.

use std::sync::Arc;

use rilq::engine::{Engine, EngineCaps, EngineConfig, SamplingParams, TokenEvent};
use rilq::eval::{greedy_decode, BackendScorer, Scorer};
use rilq::model::backend::BackendKind;
use rilq::model::{ModelDims, StudentWeights, TeacherParams};
use rilq::quant::{by_name, CalibCtx};
use rilq::tensor::Rng;

fn dims() -> ModelDims {
    ModelDims {
        name: "engine".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 48,
        seq: 16,
        batch: 4,
        group_size: 8,
    }
}

const BACKENDS: [BackendKind; 3] = BackendKind::ALL;

fn scorer(kind: BackendKind, seed: u64) -> Arc<BackendScorer> {
    let d = dims();
    let mut rng = Rng::seed(seed);
    let teacher = TeacherParams::init(&d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(&d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    Arc::new(BackendScorer::new(&d, &teacher, &student, None, kind).unwrap())
}

fn engine_over(sc: Arc<BackendScorer>, prefill_chunk: usize) -> Engine {
    Engine::start_shared(
        sc,
        EngineConfig {
            max_batch: 4,
            queue_capacity: 16,
            max_active: 4,
            prefill_chunk,
            ..EngineConfig::default()
        },
    )
}

/// Acceptance: greedy `Engine` generation — including chunked prefill —
/// reproduces PR 3's `greedy_decode` bit for bit on every backend.
#[test]
fn greedy_engine_matches_greedy_decode_bitwise_across_backends() {
    for kind in BACKENDS {
        let sc = scorer(kind, 61);
        let d = sc.dims().clone();
        let mut rng = Rng::seed(62);
        let prompt: Vec<u32> = (0..7).map(|_| rng.below(d.vocab) as u32).collect();
        let max_new = 6usize;
        let (want_toks, want_lps) = greedy_decode(sc.as_ref(), &prompt, max_new).unwrap();

        // prefill_chunk 3 < prompt length: the chunked admission path runs
        let engine = engine_over(sc, 3);
        let got = engine
            .client()
            .generate(prompt, SamplingParams::greedy(max_new))
            .unwrap()
            .wait()
            .unwrap();
        engine.shutdown();

        assert_eq!(got.tokens, want_toks, "[{kind:?}] tokens diverged from greedy_decode");
        assert_eq!(got.logps.len(), want_lps.len());
        for (i, (a, b)) in got.logps.iter().zip(&want_lps).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "[{kind:?}] logp {i} not bitwise identical: {a} vs {b}"
            );
        }
    }
}

/// Seeded temperature/top-k/top-p sampling replays identically on every
/// backend (same seed => same generation), and `seed: None` is still
/// reproducible via the documented default seed.
#[test]
fn seeded_sampling_is_deterministic_on_every_backend() {
    for kind in BACKENDS {
        let sc = scorer(kind, 63);
        let d = sc.dims().clone();
        let mut rng = Rng::seed(64);
        let prompt: Vec<u32> = (0..5).map(|_| rng.below(d.vocab) as u32).collect();
        let params = SamplingParams {
            max_new: 6,
            temperature: 0.9,
            top_k: 8,
            top_p: 0.9,
            seed: Some(42),
            stop: Vec::new(),
        };
        let engine = engine_over(sc, 4);
        let client = engine.client();
        let a = client.generate(prompt.clone(), params.clone()).unwrap().wait().unwrap();
        let b = client.generate(prompt.clone(), params.clone()).unwrap().wait().unwrap();
        assert_eq!(a, b, "[{kind:?}] same seed must replay the same generation");

        let unseeded = SamplingParams { seed: None, ..params.clone() };
        let c = client.generate(prompt.clone(), unseeded.clone()).unwrap().wait().unwrap();
        let e = client.generate(prompt, unseeded).unwrap().wait().unwrap();
        assert_eq!(c, e, "[{kind:?}] seed=None must still be reproducible");
        engine.shutdown();
    }
}

/// Different seeds at high temperature explore different continuations
/// (sampling is not secretly greedy).
#[test]
fn distinct_seeds_diverge_at_high_temperature() {
    let sc = scorer(BackendKind::Packed, 65);
    let d = sc.dims().clone();
    let mut rng = Rng::seed(66);
    let prompt: Vec<u32> = (0..4).map(|_| rng.below(d.vocab) as u32).collect();
    let engine = engine_over(sc, 8);
    let client = engine.client();
    let gen = |seed: u64| {
        let params = SamplingParams {
            max_new: 8,
            temperature: 3.0,
            seed: Some(seed),
            ..SamplingParams::greedy(8)
        };
        client.generate(prompt.clone(), params).unwrap().wait().unwrap().tokens
    };
    let outs: Vec<Vec<u32>> = (0..4).map(|s| gen(1000 + s)).collect();
    assert!(
        outs.windows(2).any(|w| w[0] != w[1]),
        "four different seeds produced identical 8-token generations: {outs:?}"
    );
    engine.shutdown();
}

/// Stop tokens truncate the generation the moment one is sampled (the
/// stop token itself is included), including the stop-at-first-token
/// edge; `max_new == 0` answers immediately with an empty generation.
#[test]
fn stop_tokens_and_degenerate_budgets() {
    let sc = scorer(BackendKind::Packed, 67);
    let d = sc.dims().clone();
    let mut rng = Rng::seed(68);
    let prompt: Vec<u32> = (0..5).map(|_| rng.below(d.vocab) as u32).collect();
    let (full, _) = greedy_decode(sc.as_ref(), &prompt, 8).unwrap();

    let engine = engine_over(sc, 8);
    let client = engine.client();

    // stop at a mid-generation token: the answer is the prefix up to and
    // including it (pick a token value not emitted earlier, since greedy
    // decodes can repeat — the first occurrence is where it stops)
    if let Some(cut) = (1..full.len()).find(|&i| !full[..i].contains(&full[i])) {
        let stopped = client
            .generate(
                prompt.clone(),
                SamplingParams { stop: vec![full[cut]], ..SamplingParams::greedy(8) },
            )
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(stopped.tokens, full[..=cut].to_vec());
        assert_eq!(stopped.logps.len(), cut + 1);
    }

    // stop-at-first-token edge
    let first = client
        .generate(
            prompt.clone(),
            SamplingParams { stop: vec![full[0]], ..SamplingParams::greedy(8) },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(first.tokens, vec![full[0]]);

    // a stop token the model never samples changes nothing
    let unstopped = client
        .generate(
            prompt.clone(),
            SamplingParams { stop: vec![d.vocab as u32 - 1], ..SamplingParams::greedy(8) },
        )
        .unwrap()
        .wait()
        .unwrap();
    let sampled_stop = unstopped.tokens.contains(&(d.vocab as u32 - 1));
    assert!(sampled_stop || unstopped.tokens == full);

    // zero budget: immediate empty answer
    let zero = client
        .generate(prompt.clone(), SamplingParams::greedy(0))
        .unwrap()
        .wait()
        .unwrap();
    assert!(zero.tokens.is_empty() && zero.logps.is_empty());

    // one-token budget equals the first greedy token
    let one = client.generate(prompt, SamplingParams::greedy(1)).unwrap().wait().unwrap();
    assert_eq!(one.tokens, full[..1].to_vec());
    engine.shutdown();
}

/// Streamed token events equal the collected `Generated` answer, token
/// for token and logp for logp — for both greedy and sampled requests.
#[test]
fn streamed_tokens_equal_collected_generation() {
    let sc = scorer(BackendKind::Merged, 69);
    let d = sc.dims().clone();
    let mut rng = Rng::seed(70);
    let prompt: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();
    let engine = engine_over(sc, 2);
    let client = engine.client();
    for params in [
        SamplingParams::greedy(7),
        SamplingParams { temperature: 1.1, top_k: 12, seed: Some(5), ..SamplingParams::greedy(7) },
    ] {
        let (stream, pending) = client.generate_stream(prompt.clone(), params).unwrap();
        let got = pending.wait().unwrap();
        let events: Vec<TokenEvent> = stream.collect();
        assert_eq!(events.len(), got.tokens.len());
        for (e, (t, lp)) in events.iter().zip(got.tokens.iter().zip(&got.logps)) {
            assert_eq!(e.token, *t);
            assert!(e.logp.to_bits() == lp.to_bits());
        }
    }
    // a zero-budget stream closes empty
    let (stream, pending) = client
        .generate_stream(prompt, SamplingParams::greedy(0))
        .unwrap();
    assert!(pending.wait().unwrap().tokens.is_empty());
    assert_eq!(stream.count(), 0);
    engine.shutdown();
}

/// `Request::Choices` through the engine equals direct
/// `Scorer::score_choices` (the prefix-reuse path), and malformed
/// choice requests err at admission.
#[test]
fn choices_request_matches_direct_choice_scoring() {
    let sc = scorer(BackendKind::Packed, 71);
    let d = sc.dims().clone();
    let mut rng = Rng::seed(72);
    let prompt: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();
    let choices: Vec<Vec<u32>> = vec![
        (0..3).map(|_| rng.below(d.vocab) as u32).collect(),
        (0..5).map(|_| rng.below(d.vocab) as u32).collect(),
        vec![rng.below(d.vocab) as u32],
    ];
    let want = sc.score_choices(&prompt, &choices).unwrap();

    let engine = engine_over(sc, 8);
    let client = engine.client();
    let got = client.choices(prompt.clone(), choices.clone()).unwrap().wait().unwrap();
    assert_eq!(got.len(), want.len());
    for (ci, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.len(), b.len(), "choice {ci}");
        for (x, y) in a.iter().zip(b) {
            assert!(x.to_bits() == y.to_bits(), "choice {ci}: {x} vs {y}");
        }
    }

    // over-window choice: rejected at admission, loop survives
    let long: Vec<u32> = (0..d.seq).map(|_| rng.below(d.vocab) as u32).collect();
    let err = client
        .choices(prompt.clone(), vec![long])
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(format!("{err}").contains("window"), "{err}");
    let err = client.choices(Vec::new(), choices).unwrap().wait().unwrap_err();
    assert!(format!("{err}").contains("non-empty"), "{err}");
    let still = client.score(prompt).unwrap().wait().unwrap();
    assert_eq!(still.len(), 5);
    let summary = engine.shutdown();
    assert_eq!(summary.choice_requests, 1.0);
    assert_eq!(summary.errors, 2.0);
}

/// Backends declare their capabilities once: the native execution
/// engines are incremental + prefix-reuse, and the descriptor drives
/// the eval routing (`mc_accuracy`) and engine admission.
#[test]
fn backend_scorers_declare_incremental_caps() {
    for kind in BACKENDS {
        let sc = scorer(kind, 73);
        assert_eq!(sc.caps(), EngineCaps::incremental(), "[{kind:?}]");
        assert!(!sc.caps().fixed_geometry);
    }
}
