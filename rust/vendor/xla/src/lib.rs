//! API-compatible **stub** of the `xla` (xla-rs / PJRT) bindings.
//!
//! The build environment has no network access and no PJRT shared library,
//! so this crate keeps the `rilq` crate compiling and its PJRT-free paths
//! (the native `LinearBackend` execution engine, quantizers, eval harness)
//! fully functional:
//!
//! * [`Literal`] is a complete host-side implementation — shape + dtype +
//!   bytes, tuple support, typed readback — because the runtime marshalling
//!   layer and its tests exercise it without ever touching a device.
//! * [`PjRtClient::cpu`] returns an error explaining that PJRT is
//!   unavailable. The runtime constructs its client lazily (on the first
//!   HLO compile/upload), and every artifact-driven caller in the repo
//!   (integration tests, benches, examples) additionally guards on
//!   `artifacts/manifest.json` existing, so those paths skip cleanly.
//!
//! To run the real HLO-artifact path, replace this path dependency in
//! `rust/Cargo.toml` with the actual `xla` crate; no `rilq` source changes
//! are needed — the API surface below matches the subset the repo uses.

use std::fmt;

/// Stub error type (the real crate's `Error` is also opaque to callers,
/// which only ever format it with `{:?}`).
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable (rilq was built against the vendored \
         stub `xla` crate; swap rust/vendor/xla for the real xla-rs bindings \
         to execute HLO artifacts)"
    ))
}

/// Element dtypes used by the rilq artifact manifests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
}

impl ElementType {
    fn size_bytes(&self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// Sealed marker for types a [`Literal`] can be read back into.
pub trait NativeType: Sized + Copy {
    #[doc(hidden)]
    const ELEMENT_TYPE: ElementType;
    #[doc(hidden)]
    fn from_le_bytes(b: &[u8]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_le_bytes(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_le_bytes(b: &[u8]) -> i32 {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for u8 {
    const ELEMENT_TYPE: ElementType = ElementType::U8;
    fn from_le_bytes(b: &[u8]) -> u8 {
        b[0]
    }
}

/// Host-side tensor value: dtype + shape + raw little-endian bytes, or a
/// tuple of nested literals. Fully functional in the stub.
#[derive(Clone)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Build a literal from raw bytes with an explicit shape.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = shape.iter().product();
        if elems * ty.size_bytes() != data.len() {
            return Err(Error(format!(
                "literal shape {shape:?} ({elems} x {}B) vs {} data bytes",
                ty.size_bytes(),
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            shape: shape.to_vec(),
            bytes: data.to_vec(),
            tuple: None,
        })
    }

    /// Scalar f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            ty: ElementType::F32,
            shape: Vec::new(),
            bytes: v.to_le_bytes().to_vec(),
            tuple: None,
        }
    }

    /// Tuple literal (what artifact executions return).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal {
            ty: ElementType::F32,
            shape: Vec::new(),
            bytes: Vec::new(),
            tuple: Some(elements),
        }
    }

    /// Total element count (product of dims; 1 for scalars).
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    /// The element dtype.
    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    /// Logical dims.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Read the buffer back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error(format!(
                "to_vec dtype mismatch: literal is {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        let sz = self.ty.size_bytes();
        Ok(self.bytes.chunks_exact(sz).map(T::from_le_bytes).collect())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple
            .ok_or_else(|| Error("to_tuple on a non-tuple literal".to_string()))
    }
}

/// Device-resident buffer handle. Never constructible through the stub
/// (every path that would create one fails at [`PjRtClient::cpu`]).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub: never constructible).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the single stub failure
/// point: it errors with an explanation instead of loading a plugin.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

/// Parsed HLO module text (stub: checks the file is readable, keeps
/// nothing — compilation requires the real bindings).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { _private: () })
    }
}

/// Computation wrapper around a parsed HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_roundtrip() {
        let data = [1.5f32, -2.0, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_shape_validation() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::U8, &[4], &[1, 2, 3]).is_err()
        );
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::scalar(1.0), Literal::scalar(2.0)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<f32>().unwrap(), vec![2.0]);
        assert!(Literal::scalar(0.0).to_tuple().is_err());
    }

    #[test]
    fn client_is_unavailable_with_clear_message() {
        let err = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(err.contains("PJRT is unavailable"), "{err}");
    }
}
