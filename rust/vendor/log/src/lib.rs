//! Minimal offline drop-in for the subset of the `log` facade this repo
//! uses: the [`Log`] trait, [`set_logger`]/[`set_max_level`], and the
//! level-named macros. Semantics mirror the real crate: macros are no-ops
//! until a logger is installed, and records above the max level filter are
//! dropped before reaching the logger.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log verbosity levels, most severe first (matches the `log` crate order,
/// so `level <= Level::Info` keeps Error/Warn/Info).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Maximum-verbosity filter.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a log record.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// A single log record.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// The logger interface.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Install the global logger (first call wins, like the real crate).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level filter.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level filter.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __dispatch(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::__dispatch($crate::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::__dispatch($crate::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::__dispatch($crate::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::__dispatch($crate::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::__dispatch($crate::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_like_real_log() {
        assert!(Level::Error < Level::Info);
        assert!(Level::Info <= Level::Info);
        assert!(Level::Debug > Level::Info);
        assert_eq!(Level::Warn.as_str(), "WARN");
    }

    #[test]
    fn macros_are_safe_without_logger() {
        info!("no logger installed: {}", 42);
        debug!("also fine");
    }
}
