//! Minimal offline drop-in for the subset of `anyhow` this repo uses.
//!
//! The build environment has no network access to crates.io, so the three
//! external dependencies are vendored as path crates. This one provides:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros,
//! and the [`Context`] extension trait for `Result` and `Option`.
//!
//! Error chains are flattened into the message at construction time
//! (`"context: cause"`), which matches how every call site in this repo
//! formats errors (`{e}` / `{e:?}`).

use std::fmt;

/// A string-backed error type compatible with `anyhow::Error` usage here.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result<T>` alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Assert a condition, early-returning an error when it fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_context() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
        assert_eq!(format!("{e:?}"), "x = 3");
        assert_eq!(fails(true).unwrap(), 7);
        assert!(fails(false).is_err());

        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let c = r.context("opening cache");
        assert!(format!("{:?}", c.unwrap_err()).contains("opening cache"));

        let o: Option<u8> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))?;
            Ok(())
        }
        assert!(format!("{}", f().unwrap_err()).contains("boom"));
    }
}
