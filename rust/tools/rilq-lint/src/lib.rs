//! `rilq-lint` — the workspace invariant checker.
//!
//! The repo's correctness story rests on conventions that rustc cannot see:
//! fixed-reduction-order kernels, panic-free serving paths, zero-alloc hot
//! loops, audited `unsafe`. This crate turns them into machine-checked rules
//! over `rust/src/**` (see the "Invariant catalog" section in the root
//! crate's `lib.rs` for the human-facing write-up):
//!
//! * **R1 — no-panic serving surface.** `unwrap`/`expect`/`panic!`/`assert!`/
//!   `unreachable!`/direct slice indexing are forbidden in `engine/`
//!   (including the PR 9 cross-request prefix index, `engine/prefix.rs`),
//!   `coordinator/serve.rs`, `model/forward.rs`, `model/kv.rs`, and
//!   `model/backend.rs`. `.lock().unwrap()` is exempt by design: a poisoned
//!   mutex means a sibling thread already panicked mid-mutation, and
//!   propagating is the only sound move (the PR 2 no-poison convention).
//!   `debug_assert!` is exempt (compiled out of release serving builds).
//!   The annotated injected panic in `engine/chaos.rs` (`ChaosScorer`, the
//!   PR 8 fault-injection harness) is the one sanctioned panic source on
//!   the serving path — it exists to exercise the engine's `catch_unwind`
//!   supervision and carries a `lint: allow(panic)` like any other excused
//!   line.
//! * **R2 — bitwise-pin guard.** `tensor/kernels.rs`, `tensor/mat.rs`, and
//!   `model/backend.rs` may not use `mul_add`, iterator `.sum()`/`.fold(`,
//!   or `par_*` reductions — any of these can silently change a pinned
//!   reduction order. Every `bitwise-pin:` comment must name tests that
//!   exist (cross-referenced against `rust/tests/**` and `#[cfg(test)]`
//!   modules).
//! * **R3 — hot-loop allocation lint.** Functions annotated `lint: hot` may
//!   not call `Vec::new`/`vec!`/`.to_vec(`/`.clone(`/`from_fn(`.
//! * **R4 — lock discipline.** A mutex guard binding (`let g = ...lock()`)
//!   may not span a call into forward/backend/scorer functions — a textual
//!   scope check that keeps the `KvArena` mutex out of compute. The prefix
//!   index is the sharpest client: attaching a cached prefix touches the
//!   arena refcount lock right next to the suffix forward, and R4 pins
//!   that the guard drops before the forward starts.
//! * **R5 — unsafe audit.** Every `unsafe` occurrence needs a `SAFETY:`
//!   comment on the same line or within the six preceding lines.
//!
//! The lexer is deliberately small and hand-rolled (zero dependencies, same
//! offline discipline as the vendored crates): it splits each line into
//! (code, comment) while tracking string/char/raw-string literals and nested
//! block comments, blanks literal contents out of the code text, and skips
//! `#[cfg(test)]` regions by brace depth. It is a *linter*, not a parser:
//! the rules are textual and the escape hatch is an annotation with a
//! mandatory reason, reviewed like any other code.

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Which invariant a [`Diagnostic`] violates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// No-panic serving surface.
    R1,
    /// Bitwise-pin guard (fixed reduction order + pins name real tests).
    R2,
    /// Hot-loop allocation lint.
    R3,
    /// Lock discipline (no guard spanning a forward/backend call).
    R4,
    /// Unsafe audit (`SAFETY:` comments).
    R5,
    /// Malformed annotation (unknown kind, missing reason, dangling).
    Ann,
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Rule::R1 => "R1 no-panic",
            Rule::R2 => "R2 bitwise-pin",
            Rule::R3 => "R3 hot-alloc",
            Rule::R4 => "R4 lock-discipline",
            Rule::R5 => "R5 unsafe-audit",
            Rule::Ann => "annotation",
        };
        f.write_str(s)
    }
}

/// One finding, formatted as `file:line: rule — message`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} — {}", self.file, self.line, self.rule, self.message)
    }
}

/// Render a diagnostic list, one per line.
pub fn render(diags: &[Diagnostic]) -> String {
    let mut out = String::new();
    for d in diags {
        out.push_str(&d.to_string());
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------------------
// Lexer: split source into per-line (code, comment), literals blanked.
// ---------------------------------------------------------------------------

/// One physical source line after lexing. `code` has string/char literal
/// contents replaced by spaces; `comment` holds the text of any `//` or
/// `/* */` comment overlapping the line.
#[derive(Clone, Debug, Default)]
pub struct Line {
    pub code: String,
    pub comment: String,
}

#[derive(Clone, Copy, PartialEq)]
enum LexState {
    Code,
    LineComment,
    /// Nested block comment depth.
    Block(u32),
    Str,
    /// Raw string with N `#` delimiters.
    RawStr(u32),
}

/// Lex `src` into per-line (code, comment) pairs.
pub fn lex(src: &str) -> Vec<Line> {
    let b: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut st = LexState::Code;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            if st == LexState::LineComment {
                st = LexState::Code;
            }
            lines.push(Line { code: std::mem::take(&mut code), comment: std::mem::take(&mut comment) });
            i += 1;
            continue;
        }
        match st {
            LexState::Code => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = LexState::LineComment;
                    comment.push_str("//");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = LexState::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push(' ');
                    st = LexState::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_word(&b, i) {
                    if let Some((hashes, skip)) = raw_str_hashes(&b, i) {
                        code.push(' ');
                        st = LexState::RawStr(hashes);
                        i += skip;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    i = lex_quote(&b, i, &mut code);
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            LexState::LineComment => {
                comment.push(c);
                i += 1;
            }
            LexState::Block(d) => {
                let next = b.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    st = if d == 1 { LexState::Code } else { LexState::Block(d - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = LexState::Block(d + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            LexState::Str => {
                if c == '\\' {
                    // a `\<newline>` continuation must leave the newline for
                    // the line accounting above, or every continuation shifts
                    // all later diagnostics up a line
                    if b.get(i + 1) == Some(&'\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    st = LexState::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            LexState::RawStr(h) => {
                if c == '"' && (0..h as usize).all(|k| b.get(i + 1 + k) == Some(&'#')) {
                    st = LexState::Code;
                    i += 1 + h as usize;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(Line { code, comment });
    }
    lines
}

fn prev_is_word(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_' || b[i - 1] == '"')
}

/// If position `i` starts a *raw* string opener (`r"`, `r#"`, `br"`, ...),
/// return (hash count, chars to skip past the opening quote). Plain byte
/// strings (`b"..."`) are handled by the escape-aware [`LexState::Str`].
fn raw_str_hashes(b: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Handle a `'` in code position: a char literal (consumed, blanked) or a
/// lifetime (left in the code text). Returns the next index.
fn lex_quote(b: &[char], i: usize, code: &mut String) -> usize {
    if b.get(i + 1) == Some(&'\\') {
        // Escaped char literal: '\n', '\\', '\'', '\x41', '\u{..}'.
        let mut j = i + 2;
        match b.get(j) {
            Some('x') => j += 3,
            Some('u') => {
                while j < b.len() && b[j] != '}' {
                    j += 1;
                }
                j += 1;
            }
            _ => j += 1,
        }
        // b[j] should now be the closing quote.
        code.push(' ');
        j + 1
    } else if b.get(i + 2) == Some(&'\'') && b.get(i + 1) != Some(&'\'') {
        // Plain char literal 'a'.
        code.push(' ');
        i + 3
    } else {
        // Lifetime or loop label: keep the tick, lex the rest as code.
        code.push('\'');
        i + 1
    }
}

// ---------------------------------------------------------------------------
// Token helpers.
// ---------------------------------------------------------------------------

fn is_word_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte offsets of `pat` in `code` where word-char pattern ends sit on word
/// boundaries (so `assert!` does not match inside `debug_assert!`).
fn token_positions(code: &str, pat: &str) -> Vec<usize> {
    let cb = code.as_bytes();
    let pb = pat.as_bytes();
    let mut out = Vec::new();
    if pb.is_empty() {
        return out;
    }
    let mut start = 0usize;
    while let Some(off) = code[start..].find(pat) {
        let i = start + off;
        let pre_ok = !is_word_byte(pb[0]) || i == 0 || !is_word_byte(cb[i - 1]);
        let end = i + pb.len();
        let post_ok = !is_word_byte(pb[pb.len() - 1]) || end >= cb.len() || !is_word_byte(cb[end]);
        if pre_ok && post_ok {
            out.push(i);
        }
        start = i + 1;
    }
    out
}

fn has_token(code: &str, pat: &str) -> bool {
    !token_positions(code, pat).is_empty()
}

/// Direct slice/array indexing: a `[` immediately preceded by an identifier
/// char, `)`, or `]` (excludes macros `vec![`, attributes `#[`, types
/// `&[f32]`, and generics `<[T]>`).
fn has_direct_index(code: &str) -> bool {
    let b = code.as_bytes();
    for i in 1..b.len() {
        if b[i] == b'[' {
            let p = b[i - 1];
            if is_word_byte(p) || p == b')' || p == b']' {
                return true;
            }
        }
    }
    false
}

/// Name of the first function declared on this line, if any.
fn fn_name(code: &str) -> Option<String> {
    let i = *token_positions(code, "fn").first()?;
    let rest = code[i + 2..].trim_start();
    let name: String =
        rest.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

// ---------------------------------------------------------------------------
// Annotation grammar.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq)]
enum Ann {
    Hot,
    AllowPanic,
    AllowIndexing,
    AllowReduce,
}

/// Strip comment markers/leading decoration so annotation detection only
/// fires on comments that *start* with the marker (doc prose that mentions
/// the grammar mid-sentence stays inert).
fn stripped_comment(comment: &str) -> &str {
    comment.trim_start_matches(['/', '!', '*', ' '])
}

/// Parse a `lint:` annotation comment. `None` when the comment is not an
/// annotation; `Some(Err(..))` for a malformed one.
fn parse_ann(stripped: &str) -> Option<Result<Ann, String>> {
    let rest = stripped.strip_prefix("lint:")?.trim_start();
    if let Some(after) = rest.strip_prefix("hot") {
        if after.is_empty() || !after.starts_with(|c: char| c.is_ascii_alphanumeric() || c == '_') {
            return Some(Ok(Ann::Hot));
        }
    }
    for (pat, ann) in [
        ("allow(panic)", Ann::AllowPanic),
        ("allow(indexing)", Ann::AllowIndexing),
        ("allow(reduce)", Ann::AllowReduce),
    ] {
        if let Some(after) = rest.strip_prefix(pat) {
            let reason = after.trim_start_matches([' ', '\u{2014}', '-', ':']).trim();
            if reason.is_empty() {
                return Some(Err(format!("`lint: {pat}` requires a reason after the kind")));
            }
            return Some(Ok(ann));
        }
    }
    Some(Err(format!("unknown lint annotation `lint: {rest}`")))
}

/// Parse a `bitwise-pin:` comment into the test names it cites. `None` when
/// the comment is not a pin; `Some(Err(..))` when the pin names nothing.
fn parse_pin(stripped: &str) -> Option<Result<Vec<String>, String>> {
    let rest = stripped.strip_prefix("bitwise-pin:")?;
    let mut names = Vec::new();
    for tok in rest.split([',', ' ', '\t']).filter(|t| !t.is_empty()) {
        if tok.bytes().all(is_word_byte) {
            names.push(tok.to_string());
        } else {
            break; // trailing prose after the name list
        }
    }
    if names.is_empty() {
        Some(Err("`bitwise-pin:` names no test".to_string()))
    } else {
        Some(Ok(names))
    }
}

// ---------------------------------------------------------------------------
// File classification.
// ---------------------------------------------------------------------------

fn norm(label: &str) -> String {
    label.replace('\\', "/")
}

/// Files on the no-panic serving surface (R1).
fn in_r1_scope(label: &str) -> bool {
    let p = norm(label);
    p.starts_with("engine/")
        || p.contains("/engine/")
        || p.ends_with("coordinator/serve.rs")
        || p.ends_with("model/forward.rs")
        || p.ends_with("model/kv.rs")
        || p.ends_with("model/backend.rs")
}

/// Files under the bitwise-pin reduction-order guard (R2).
fn in_r2_scope(label: &str) -> bool {
    let p = norm(label);
    p.ends_with("tensor/kernels.rs") || p.ends_with("tensor/mat.rs") || p.ends_with("model/backend.rs")
}

// ---------------------------------------------------------------------------
// Pattern tables.
// ---------------------------------------------------------------------------

/// R1: panicking constructs (token, human label). `.unwrap()` gets special
/// handling for the `.lock().unwrap()` poisoned-mutex exemption.
const PANIC_TOKENS: [(&str, &str); 6] = [
    (".unwrap()", "`.unwrap()`"),
    (".expect(", "`.expect(..)`"),
    ("panic!", "`panic!`"),
    ("assert!", "`assert!`"),
    ("assert_eq!", "`assert_eq!`"),
    ("unreachable!", "`unreachable!`"),
];

/// R2: reduction-order hazards.
const REDUCE_TOKENS: [&str; 7] =
    ["mul_add", ".sum()", ".sum::<", ".fold(", "par_iter", "into_par_iter", "par_chunks"];

/// R3: allocation calls banned inside `lint: hot` functions.
const ALLOC_TOKENS: [&str; 5] = ["Vec::new", "vec!", ".to_vec(", ".clone(", "from_fn("];

/// R4: compute entry points a live mutex guard must not reach.
const FORWARD_TOKENS: [&str; 10] = [
    ".forward(",
    "forward_trace",
    "forward_step",
    "forward_batch",
    "forward_prefill",
    "cache_forward",
    "attend_cached(",
    "score_batch(",
    "score_all(",
    "score_choices(",
];

// ---------------------------------------------------------------------------
// Test-name collection (for bitwise-pin cross-referencing).
// ---------------------------------------------------------------------------

/// Collect `#[test]` function names across `(label, source)` pairs —
/// `rust/tests/**` and every `#[cfg(test)]` module alike.
pub fn collect_test_names(sources: &[(String, String)]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for (_, src) in sources {
        let mut armed = false;
        for ln in lex(src) {
            let ct = ln.code.trim();
            if ct.is_empty() {
                continue;
            }
            if ct.contains("#[test]") {
                armed = true;
                continue;
            }
            if armed {
                if ct.starts_with("#[") || ct.starts_with("#![") {
                    continue; // e.g. #[should_panic] between #[test] and fn
                }
                if let Some(name) = fn_name(ct) {
                    names.insert(name);
                }
                armed = false;
            }
        }
    }
    names
}

// ---------------------------------------------------------------------------
// The rule engine.
// ---------------------------------------------------------------------------

struct FnCtx {
    body_depth: i32,
    hot: bool,
    allow_indexing: bool,
}

struct Guard {
    name: String,
    depth: i32,
    line: usize,
    reported: bool,
}

/// Lint one file. `label` is the path relative to the crate root (used for
/// rule scoping and diagnostics); `tests` is the known-test-name universe
/// for `bitwise-pin:` validation.
pub fn lint_file(label: &str, src: &str, tests: &BTreeSet<String>) -> Vec<Diagnostic> {
    let lines = lex(src);
    let r1 = in_r1_scope(label);
    let r2 = in_r2_scope(label);
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut depth: i32 = 0;
    // (armed_at_depth, entered_body)
    let mut test_skip: Option<(i32, bool)> = None;
    let mut pending_hot = false;
    let mut pending_allow_idx = false;
    let mut pending_ann_line = 0usize;
    let mut carried_panic = false;
    let mut carried_reduce = false;
    let mut fn_stack: Vec<FnCtx> = Vec::new();
    // (hot, allow_indexing): a `fn` seen, waiting for its opening brace.
    let mut pending_fn: Option<(bool, bool)> = None;
    let mut guards: Vec<Guard> = Vec::new();

    for (idx, ln) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let code = ln.code.as_str();
        let code_trim = code.trim();
        let opens = code.matches('{').count() as i32;
        let closes = code.matches('}').count() as i32;
        let depth_end = depth + opens - closes;
        let is_blank = code_trim.is_empty();
        let is_attr = code_trim.starts_with("#[") || code_trim.starts_with("#![");

        // ---- #[cfg(test)] region skipping -------------------------------
        if let Some((d, entered)) = test_skip {
            if !entered {
                if depth_end > d {
                    test_skip = Some((d, true));
                } else if code_trim.ends_with(';') {
                    test_skip = None; // attribute landed on a braceless item
                }
            }
            if let Some((d, true)) = test_skip {
                if depth_end <= d {
                    test_skip = None;
                }
                depth = depth_end;
                continue;
            }
            if test_skip.is_some() {
                depth = depth_end;
                continue;
            }
        }
        if code.contains("#[cfg(test)]") && !code_trim.ends_with(';') {
            test_skip = Some((depth, false));
            if depth_end > depth {
                test_skip = Some((depth, true));
            }
            depth = depth_end;
            continue;
        }

        // ---- annotations ------------------------------------------------
        let sc = stripped_comment(&ln.comment);
        let mut allow_panic = carried_panic;
        let mut allow_reduce = carried_reduce;
        if !is_blank && !is_attr {
            carried_panic = false;
            carried_reduce = false;
        }
        match parse_ann(sc) {
            Some(Err(msg)) => {
                diags.push(Diagnostic {
                    file: label.to_string(),
                    line: lineno,
                    rule: Rule::Ann,
                    message: msg,
                });
            }
            Some(Ok(Ann::Hot)) => {
                pending_hot = true;
                pending_ann_line = lineno;
            }
            Some(Ok(Ann::AllowIndexing)) => {
                pending_allow_idx = true;
                pending_ann_line = lineno;
            }
            Some(Ok(Ann::AllowPanic)) => {
                allow_panic = true;
                if is_blank || is_attr {
                    carried_panic = true;
                }
            }
            Some(Ok(Ann::AllowReduce)) => {
                allow_reduce = true;
                if is_blank || is_attr {
                    carried_reduce = true;
                }
            }
            None => {}
        }
        match parse_pin(sc) {
            Some(Err(msg)) => {
                diags.push(Diagnostic {
                    file: label.to_string(),
                    line: lineno,
                    rule: Rule::Ann,
                    message: msg,
                });
            }
            Some(Ok(names)) => {
                for name in names {
                    if !tests.contains(&name) {
                        diags.push(Diagnostic {
                            file: label.to_string(),
                            line: lineno,
                            rule: Rule::R2,
                            message: format!(
                                "`bitwise-pin: {name}` names no known test \
                                 (checked rust/tests/** and #[cfg(test)] modules)"
                            ),
                        });
                    }
                }
            }
            None => {}
        }

        // ---- attach function-level annotations --------------------------
        if !is_blank && !is_attr {
            if has_token(code, "fn") {
                pending_fn = Some((pending_hot, pending_allow_idx));
                pending_hot = false;
                pending_allow_idx = false;
            } else if pending_hot || pending_allow_idx {
                diags.push(Diagnostic {
                    file: label.to_string(),
                    line: pending_ann_line,
                    rule: Rule::Ann,
                    message: "function-level `lint:` annotation does not precede a function"
                        .to_string(),
                });
                pending_hot = false;
                pending_allow_idx = false;
            }
        }
        if let Some((hot, allow_idx)) = pending_fn {
            if opens > 0 {
                fn_stack.push(FnCtx { body_depth: depth + 1, hot, allow_indexing: allow_idx });
                pending_fn = None;
            } else if code_trim.ends_with(';') {
                pending_fn = None; // trait method declaration, no body
            }
        }

        // ---- R1: no-panic serving surface --------------------------------
        if r1 && !is_blank {
            for (tok, human) in PANIC_TOKENS {
                let hits = token_positions(code, tok);
                if hits.is_empty() {
                    continue;
                }
                let exempt = tok == ".unwrap()"
                    && hits.iter().all(|&i| code[..i].ends_with("lock()"));
                if exempt || allow_panic {
                    continue;
                }
                diags.push(Diagnostic {
                    file: label.to_string(),
                    line: lineno,
                    rule: Rule::R1,
                    message: format!(
                        "{human} on the serving surface — return Err or annotate \
                         `// lint: allow(panic) — <reason>`"
                    ),
                });
            }
            let fn_allows_idx = fn_stack.iter().any(|f| f.allow_indexing);
            if has_direct_index(code) && !allow_panic && !fn_allows_idx {
                diags.push(Diagnostic {
                    file: label.to_string(),
                    line: lineno,
                    rule: Rule::R1,
                    message: "direct slice indexing on the serving surface — use a checked \
                              accessor or annotate `// lint: allow(indexing) — <reason>` on \
                              the function"
                        .to_string(),
                });
            }
        }

        // ---- R2: bitwise-pin guard ---------------------------------------
        if r2 && !is_blank && !allow_reduce {
            for tok in REDUCE_TOKENS {
                if has_token(code, tok) {
                    diags.push(Diagnostic {
                        file: label.to_string(),
                        line: lineno,
                        rule: Rule::R2,
                        message: format!(
                            "`{tok}` can change a pinned reduction order — use the fixed-order \
                             kernels or annotate `// lint: allow(reduce) — <reason>`"
                        ),
                    });
                }
            }
        }

        // ---- R3: hot-loop allocations ------------------------------------
        if fn_stack.iter().any(|f| f.hot) && !is_blank {
            for tok in ALLOC_TOKENS {
                if has_token(code, tok) {
                    diags.push(Diagnostic {
                        file: label.to_string(),
                        line: lineno,
                        rule: Rule::R3,
                        message: format!(
                            "`{tok}` allocates inside a `lint: hot` function — reuse \
                             thread-local scratch instead"
                        ),
                    });
                }
            }
        }

        // ---- R4: lock discipline -----------------------------------------
        if !is_blank {
            // New guard binding on this line?
            if code.contains(".lock()") {
                if let Some(name) = guard_binding_name(code) {
                    guards.push(Guard { name, depth: depth_end, line: lineno, reported: false });
                }
            }
            if !guards.is_empty() {
                let crosses = FORWARD_TOKENS.iter().find(|tok| has_token(code, tok));
                if let Some(tok) = crosses {
                    for g in guards.iter_mut().filter(|g| !g.reported) {
                        diags.push(Diagnostic {
                            file: label.to_string(),
                            line: lineno,
                            rule: Rule::R4,
                            message: format!(
                                "mutex guard `{}` (taken on line {}) is live across `{tok}` — \
                                 drop the guard before entering compute",
                                g.name, g.line
                            ),
                        });
                        g.reported = true;
                    }
                }
                // Explicit early drop releases the guard.
                guards.retain(|g| !has_token(code, &format!("drop({})", g.name)));
            }
        }

        // ---- R5: unsafe audit --------------------------------------------
        if !is_blank && has_token(code, "unsafe") {
            let mut ok = ln.comment.contains("SAFETY:");
            for back in 1..=6 {
                if ok || back > idx {
                    break;
                }
                ok = lines[idx - back].comment.contains("SAFETY:");
            }
            if !ok {
                diags.push(Diagnostic {
                    file: label.to_string(),
                    line: lineno,
                    rule: Rule::R5,
                    message: "`unsafe` without a `// SAFETY:` comment on the preceding lines"
                        .to_string(),
                });
            }
        }

        // ---- scope bookkeeping -------------------------------------------
        while fn_stack.last().is_some_and(|f| f.body_depth > depth_end) {
            fn_stack.pop();
        }
        guards.retain(|g| depth_end >= g.depth);
        depth = depth_end;
    }
    diags
}

/// Extract the binding name from `let [mut] NAME = ....lock()...`, if the
/// line creates a named guard (a `.lock()` used as a temporary is dropped at
/// the end of its statement and never becomes a guard).
fn guard_binding_name(code: &str) -> Option<String> {
    let i = *token_positions(code, "let").first()?;
    let mut rest = code[i + 3..].trim_start();
    if let Some(r) = rest.strip_prefix("mut ") {
        rest = r.trim_start();
    }
    let name: String =
        rest.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
    if name.is_empty() || name.starts_with(|c: char| c.is_ascii_uppercase()) {
        // Pattern bindings (`let Ok(g) = ...`) are out of scope for the
        // textual check; none exist on the lock paths today.
        return None;
    }
    Some(name)
}

// ---------------------------------------------------------------------------
// Tree walking.
// ---------------------------------------------------------------------------

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint `<root>/src/**` against the R1–R5 catalog, cross-referencing
/// `bitwise-pin:` names against tests found in both `<root>/src/**` and
/// `<root>/tests/**`. `root` is the crate root holding `src/` (i.e. `rust/`).
pub fn lint_tree(root: &Path) -> io::Result<Vec<Diagnostic>> {
    let mut src_files = Vec::new();
    walk(&root.join("src"), &mut src_files)?;
    let mut test_files = Vec::new();
    let tests_dir = root.join("tests");
    if tests_dir.is_dir() {
        walk(&tests_dir, &mut test_files)?;
    }
    let n_src = src_files.len();
    let mut sources: Vec<(String, String)> = Vec::new();
    for p in src_files.iter().chain(test_files.iter()) {
        let label = p
            .strip_prefix(root)
            .map(|r| norm(&r.to_string_lossy()))
            .unwrap_or_else(|_| norm(&p.to_string_lossy()));
        sources.push((label, fs::read_to_string(p)?));
    }
    let tests = collect_test_names(&sources);
    let mut diags = Vec::new();
    for (label, src) in sources.iter().take(n_src) {
        diags.extend(lint_file(label, src, &tests));
    }
    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(diags)
}

// ---------------------------------------------------------------------------
// Tests: each bad fixture trips exactly its rule; allowed forms pass.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(label: &str, src: &str, tests: &[&str]) -> Vec<Diagnostic> {
        let set: BTreeSet<String> = tests.iter().map(|s| s.to_string()).collect();
        lint_file(label, src, &set)
    }

    fn rules(diags: &[Diagnostic]) -> BTreeSet<Rule> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn r1_fixture_trips_only_r1() {
        let d = lint("engine/fixture.rs", include_str!("../fixtures/r1_bad.rs"), &[]);
        assert!(!d.is_empty(), "expected R1 findings");
        assert_eq!(rules(&d), BTreeSet::from([Rule::R1]), "{}", render(&d));
        // unwrap + assert! + indexing all reported
        assert!(d.len() >= 3, "{}", render(&d));
    }

    #[test]
    fn r1_allowed_fixture_is_clean() {
        let d = lint("engine/fixture.rs", include_str!("../fixtures/r1_allowed.rs"), &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn r1_lock_unwrap_is_exempt() {
        let src = "fn f(m: &M) -> usize {\n    m.inner.lock().unwrap().len()\n}\n";
        let d = lint("engine/fixture.rs", src, &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn r1_does_not_apply_outside_the_serving_surface() {
        let d = lint("quant/fixture.rs", include_str!("../fixtures/r1_bad.rs"), &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn r2_fixture_trips_only_r2() {
        let d = lint("tensor/kernels.rs", include_str!("../fixtures/r2_bad.rs"), &[]);
        assert!(!d.is_empty(), "expected R2 findings");
        assert_eq!(rules(&d), BTreeSet::from([Rule::R2]), "{}", render(&d));
        assert!(d.len() >= 2, "mul_add and .sum() both reported: {}", render(&d));
    }

    #[test]
    fn r2_unknown_pin_is_reported() {
        let d = lint("tensor/kernels.rs", include_str!("../fixtures/r2_pin_unknown.rs"), &[]);
        assert_eq!(rules(&d), BTreeSet::from([Rule::R2]), "{}", render(&d));
    }

    #[test]
    fn r2_known_pin_and_allowed_reduce_pass() {
        let d = lint(
            "tensor/kernels.rs",
            include_str!("../fixtures/r2_allowed.rs"),
            &["dot4_is_bitwise_four_dots"],
        );
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn r3_fixture_trips_only_r3() {
        let d = lint("quant/fixture.rs", include_str!("../fixtures/r3_bad.rs"), &[]);
        assert!(!d.is_empty(), "expected R3 findings");
        assert_eq!(rules(&d), BTreeSet::from([Rule::R3]), "{}", render(&d));
        assert!(d.len() >= 2, "Vec::new and to_vec both reported: {}", render(&d));
    }

    #[test]
    fn r3_allowed_fixture_is_clean() {
        let d = lint("quant/fixture.rs", include_str!("../fixtures/r3_allowed.rs"), &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn r3_only_applies_inside_hot_functions() {
        let src = "pub fn cold() -> Vec<f32> {\n    let v = Vec::new();\n    v\n}\n";
        let d = lint("quant/fixture.rs", src, &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn r4_fixture_trips_only_r4() {
        let d = lint("quant/fixture.rs", include_str!("../fixtures/r4_bad.rs"), &[]);
        assert!(!d.is_empty(), "expected an R4 finding");
        assert_eq!(rules(&d), BTreeSet::from([Rule::R4]), "{}", render(&d));
    }

    #[test]
    fn r4_allowed_fixture_is_clean() {
        let d = lint("quant/fixture.rs", include_str!("../fixtures/r4_allowed.rs"), &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn r1_covers_the_prefix_index() {
        // the cross-request prefix index (PR 9) is on the serving
        // surface: trie-shaped unwrap/expect/indexing all trip R1
        let d = lint("engine/prefix.rs", include_str!("../fixtures/r1_prefix_bad.rs"), &[]);
        assert!(!d.is_empty(), "expected R1 findings");
        assert_eq!(rules(&d), BTreeSet::from([Rule::R1]), "{}", render(&d));
        assert!(d.len() >= 3, "unwrap + expect + indexing all reported: {}", render(&d));
    }

    #[test]
    fn r1_covers_the_workload_generator() {
        // trace generation (PR 10) runs inline on the serving surface
        // (serve-bench and the chaos harness call it), so unwrap/expect/
        // assert/indexing in `engine/workload.rs` all trip R1
        let d = lint("engine/workload.rs", include_str!("../fixtures/r1_workload_bad.rs"), &[]);
        assert!(!d.is_empty(), "expected R1 findings");
        assert_eq!(rules(&d), BTreeSet::from([Rule::R1]), "{}", render(&d));
        assert!(d.len() >= 4, "unwrap + expect + assert + indexing all reported: {}", render(&d));
    }

    #[test]
    fn r4_covers_load_aware_dispatch() {
        // routing that holds a lock on the shared load registry across a
        // forward serializes the fleet behind the router — the R4 shape
        // the atomics-only LoadView (PR 10) exists to rule out. The
        // fixture is R1-clean so the `engine/dispatch.rs` label trips R4
        // alone.
        let d = lint("engine/dispatch.rs", include_str!("../fixtures/r4_dispatch_bad.rs"), &[]);
        assert!(!d.is_empty(), "expected an R4 finding");
        assert_eq!(rules(&d), BTreeSet::from([Rule::R4]), "{}", render(&d));
    }

    #[test]
    fn r4_covers_the_prefix_index() {
        // holding the arena refcount guard across a cache-hit suffix
        // forward is exactly the deadlock shape R4 exists to catch —
        // and the fixture is R1-clean, so the label trips R4 alone
        let d = lint("engine/prefix.rs", include_str!("../fixtures/r4_prefix_bad.rs"), &[]);
        assert!(!d.is_empty(), "expected an R4 finding");
        assert_eq!(rules(&d), BTreeSet::from([Rule::R4]), "{}", render(&d));
    }

    #[test]
    fn r5_fixture_trips_only_r5() {
        let d = lint("quant/fixture.rs", include_str!("../fixtures/r5_bad.rs"), &[]);
        assert_eq!(rules(&d), BTreeSet::from([Rule::R5]), "{}", render(&d));
    }

    #[test]
    fn r5_allowed_fixture_is_clean() {
        let d = lint("quant/fixture.rs", include_str!("../fixtures/r5_allowed.rs"), &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn lexer_ignores_strings_and_comments() {
        let src = "fn f() {\n    // calls unwrap() and panic! in prose\n    \
                   let s = \"x.unwrap() assert! v[i] unsafe\";\n    \
                   let r = r#\"panic! w[j]\"#;\n    let _ = (s, r);\n}\n";
        let d = lint("engine/fixture.rs", src, &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn lexer_handles_char_literals_and_lifetimes() {
        let lines = lex("fn g<'a>(x: &'a [u8]) -> u8 {\n    let c = '[';\n    x.first().copied().unwrap_or(c as u8)\n}\n");
        assert!(lines[1].code.contains("let c ="));
        assert!(!lines[1].code.contains('['), "char literal must be blanked: {:?}", lines[1].code);
    }

    #[test]
    fn cfg_test_modules_are_skipped() {
        let src = "pub fn ok() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    \
                   fn t() {\n        let v = vec![1];\n        assert_eq!(v[0], 1);\n        \
                   v.first().unwrap();\n    }\n}\n";
        let d = lint("engine/fixture.rs", src, &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn test_names_are_collected_from_cfg_test_modules() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn my_pinned_test() {}\n    \
                   #[test]\n    #[should_panic]\n    fn other_test() {}\n}\n"
            .to_string();
        let names = collect_test_names(&[("x.rs".to_string(), src)]);
        assert!(names.contains("my_pinned_test"));
        assert!(names.contains("other_test"));
    }

    #[test]
    fn annotation_without_reason_is_malformed() {
        let src = "fn f(v: &[u32]) -> u32 {\n    // lint: allow(panic)\n    v.first().copied().unwrap_or(0)\n}\n";
        let d = lint("engine/fixture.rs", src, &[]);
        assert_eq!(rules(&d), BTreeSet::from([Rule::Ann]), "{}", render(&d));
    }

    #[test]
    fn dangling_hot_annotation_is_malformed() {
        let src = "// lint: hot\nstatic X: u32 = 0;\n";
        let d = lint("quant/fixture.rs", src, &[]);
        assert_eq!(rules(&d), BTreeSet::from([Rule::Ann]), "{}", render(&d));
    }

    #[test]
    fn doc_prose_mentioning_the_grammar_is_inert() {
        let src = "//! Functions annotated `// lint: hot` may not allocate; pins use\n\
                   //! `// bitwise-pin: <test_name>` comments.\npub fn ok() {}\n";
        let d = lint("quant/fixture.rs", src, &[]);
        assert!(d.is_empty(), "{}", render(&d));
    }

    #[test]
    fn string_continuations_do_not_shift_line_numbers() {
        // a `\<newline>` inside a string literal continues it on the next
        // source line; the lexer must still emit one entry per source line
        // or every diagnostic after the continuation points one line high
        let src = "fn f(e: &str) -> String {\n    format!(\n        \"a long message \\\n         split over lines: {e}\"\n    )\n}\nfn g(v: &[u32]) -> u32 {\n    v.first().copied().unwrap()\n}\n";
        let d = lint("engine/fixture.rs", src, &[]);
        assert_eq!(d.len(), 1, "{}", render(&d));
        assert_eq!(d[0].line, 8, "unwrap is on source line 8: {}", render(&d));
    }
}
