//! CLI entry point: `cargo run -p rilq-lint [crate-root]`.
//!
//! Lints `<root>/src/**` against the R1–R5 invariant catalog and exits
//! nonzero on any finding. With no argument the root defaults to the main
//! `rilq` crate two levels up from this tool (i.e. `rust/`), so the CI
//! invocation is just `cargo run -p rilq-lint`.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args_os()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join(".."));
    match rilq_lint::lint_tree(&root) {
        Err(e) => {
            eprintln!("rilq-lint: cannot walk {}: {e}", root.display());
            ExitCode::from(2)
        }
        Ok(diags) if diags.is_empty() => {
            println!("rilq-lint: clean — R1–R5 hold across {}", root.join("src").display());
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for d in &diags {
                println!("{d}");
            }
            eprintln!("rilq-lint: {} violation(s)", diags.len());
            ExitCode::FAILURE
        }
    }
}
