//! The shipped tree must be lint-clean: this is the same check CI runs via
//! `cargo run -p rilq-lint`, expressed as a test so `cargo test -p rilq-lint`
//! is self-contained.

#[test]
fn shipped_tree_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let diags = rilq_lint::lint_tree(&root).expect("walk rust/src");
    assert!(
        diags.is_empty(),
        "rust/src violates the R1-R5 invariant catalog:\n{}",
        rilq_lint::render(&diags)
    );
}
