// The clean form of the R4 fixture: the guard's block closes before the
// forward call, so no lock is held across compute.
pub fn step(arena: &Arena, backend: &B, x: &Mat) -> Mat {
    let n = {
        let g = arena.inner.lock().unwrap();
        g.len()
    };
    backend.forward(x).scaled(n as f32)
}
