// Known-bad R5 fixture: an unsafe block with no SAFETY comment anywhere
// in the six preceding lines.
pub fn reinterpret(data: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}
