// The annotated form of the R2 fixture: the reduce is explicitly allowed
// with a reason (exact integer arithmetic) and the pin names a test the
// unit test registers as existing.
// bitwise-pin: dot4_is_bitwise_four_dots
pub fn total_bytes(xs: &[usize]) -> usize {
    // lint: allow(reduce) — usize accumulation is exact and order-free
    xs.iter().sum()
}
