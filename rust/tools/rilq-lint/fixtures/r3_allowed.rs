// The clean form of the R3 fixture: a hot function that only writes
// through caller-provided buffers.
// lint: hot
pub fn accumulate(acc: &mut [f32], x: &[f32]) {
    for (a, v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}
