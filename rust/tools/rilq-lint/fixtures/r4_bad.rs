// Known-bad R4 fixture: the arena mutex guard is still live when the code
// calls into the forward path — compute under a scheduler lock.
pub fn step(arena: &Arena, backend: &B, x: &Mat) -> Mat {
    let mut g = arena.inner.lock().unwrap();
    g.push(1);
    let y = backend.forward(x);
    drop(g);
    y
}
