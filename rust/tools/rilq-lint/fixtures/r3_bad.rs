// Known-bad R3 fixture: a `lint: hot` function that allocates twice.
// lint: hot
pub fn gather(rows: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    out.extend_from_slice(rows);
    out.to_vec()
}
