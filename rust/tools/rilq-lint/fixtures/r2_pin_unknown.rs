// Known-bad R2 fixture: the pin cites a test that exists nowhere in
// rust/tests/** or any #[cfg(test)] module.
// bitwise-pin: no_such_test_anywhere
pub fn pinned(a: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for v in a {
        acc += v;
    }
    acc
}
