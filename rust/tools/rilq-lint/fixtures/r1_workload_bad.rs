// Known-bad R1 fixture shaped like the workload trace generator
// (PR 10): the tenant draw unwraps the weighted pick, the arrival loop
// asserts on the phase clock, and the event sink indexes the tenant
// table directly. The unit test labels this file `engine/workload.rs` —
// trace generation runs on the serving surface (serve-bench and the
// chaos harness call it inline), so it inherits the no-panic rule like
// the rest of `engine/`. Lexed by the linter, never compiled.
pub fn generate(cfg: &TraceConfig, rng: &mut Rng) -> Vec<TraceEvent> {
    let mut out = Vec::new();
    let class = cfg.tenants.first().unwrap();
    let weight = cfg.weights[rng.sample_weighted(&cfg.weights)];
    assert!(weight > 0.0, "a tenant class must carry weight");
    let max_new = cfg.gen.sample(rng).expect("bounded sample");
    out.push(TraceEvent { tenant: class.name.clone(), max_new });
    out
}
