// Known-bad R1 fixture: unwrap, assert!, and direct indexing on a file
// linted under the serving-surface scope (the unit test labels this file
// `engine/fixture.rs`). Lexed by the linter, never compiled.
pub fn lookup(v: &[u32], i: usize) -> u32 {
    let first = v.first().unwrap();
    assert!(i > 0);
    v[i] + first
}
