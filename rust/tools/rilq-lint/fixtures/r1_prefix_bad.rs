// Known-bad R1 fixture shaped like the cross-request prefix index
// (PR 9): the radix walk unwraps a child lookup, expects a block
// handle, and indexes the refcount table directly. The unit test
// labels this file `engine/prefix.rs` — the index is on the no-panic
// serving surface like the rest of `engine/`. Lexed by the linter,
// never compiled.
pub fn attach(ix: &mut Index, tokens: &[u32]) -> usize {
    let child = ix.children.first_mut().unwrap();
    let block = child.blocks.last().expect("leaf holds blocks");
    ix.refs[block.id] += 1;
    child.tokens.len().min(tokens.len())
}
