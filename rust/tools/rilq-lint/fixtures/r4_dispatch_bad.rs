// Known-bad R4 fixture shaped like load-aware dispatch (PR 10): the
// routing decision takes a lock on the shared load registry and then
// drives the replica's forward while still holding it — the exact shape
// that serializes the whole fleet behind one router. The real LoadView
// uses plain atomics precisely to make this impossible. Kept R1-clean
// on purpose (`.lock().unwrap()` is exempt, no direct indexing) so the
// unit test can pin that the `engine/dispatch.rs` label trips R4 alone.
// Lexed by the linter, never compiled.
pub fn route_and_score(view: &LoadView, scorer: &S, batch: &[Vec<u32>]) -> Mat {
    let mut g = view.inner.lock().unwrap();
    let replica = g.least_loaded();
    g.bump_queue_depth(replica);
    let out = scorer.score_batch(batch);
    drop(g);
    out
}
