// Known-bad R2 fixture: mul_add and an iterator sum inside a file linted
// under the bitwise-pin scope (labelled `tensor/kernels.rs` by the test).
// Either can silently change a pinned reduction order.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x.mul_add(*y, 0.0)).sum()
}
