// Known-bad R4 fixture shaped like the cross-request prefix index
// (PR 9): the arena refcount guard is still live when the cache-hit
// suffix is forwarded — compute under the scheduler lock. Kept R1-clean
// on purpose (`.lock().unwrap()` is exempt, no direct indexing) so the
// unit test can pin that the `engine/prefix.rs` label trips R4 alone.
// Lexed by the linter, never compiled.
pub fn attach_and_prefill(ix: &Index, scorer: &S, suffix: &[u32], cache: &mut KvCache) -> Mat {
    let mut g = ix.arena.inner.lock().unwrap();
    g.pin_blocks(cache);
    let lg = scorer.cache_forward(suffix, cache);
    drop(g);
    lg
}
