// The audited form of the R5 fixture: the unsafe block carries a SAFETY
// comment within the six-line window.
pub fn reinterpret(data: &[f32]) -> &[u8] {
    // SAFETY: every f32 bit pattern is a valid byte sequence; the pointer
    // is derived from a live slice and the length is its exact byte span.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}
