// The annotated form of the R1 fixture: every panic path carries a lint
// annotation with a reason, so the serving-surface scope accepts it.
// lint: allow(indexing) — i is caller-bounded in this fixture
pub fn lookup(v: &[u32], i: usize) -> u32 {
    // lint: allow(panic) — fixture invariant: v is non-empty by contract
    let first = v.first().unwrap();
    v[i] + first
}
