//! Rank-insensitivity demo (the paper's core claim, Fig. 3(a) + Table 4):
//! sweep adapter rank for SVD vs RILQ compensation at 2-bit and watch SVD
//! degrade while RILQ stays flat.
//!
//! ```bash
//! make artifacts && cargo run --release --example rank_sweep [-- --fast]
//! ```

use rilq::experiments::pipeline::Lab;
use rilq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    let mut lab = Lab::new(&rt);
    if std::env::args().any(|a| a == "--fast") {
        lab.pretrain_steps_override = Some(200);
        lab.calib.max_steps = 40;
        lab.calib.n_samples = 64;
    }
    let (dims, teacher, _) = lab.teacher("small")?;
    let student = lab.quantize(&dims, &teacher, "nf", 2)?;

    println!("rank   SVD Wiki2-PPL   RILQ Wiki2-PPL");
    let mut svd_ppls = Vec::new();
    let mut rilq_ppls = Vec::new();
    for rank in [4usize, 16, 64] {
        let (st, ad_svd) = lab.loftq(&dims, &teacher, "nf", 2, rank, 1)?;
        let svd_ppl = lab
            .evaluate(&lab.student_scorer(&dims, &teacher, &st, &ad_svd)?, &dims)?
            .ppl_wiki;
        let init = lab.default_adapters(&dims, rank);
        let (ad, _) = lab.compensate(&dims, &teacher, &student, &init, "model_gt", "nf2")?;
        let rilq_ppl = lab
            .evaluate(&lab.student_scorer(&dims, &teacher, &student, &ad)?, &dims)?
            .ppl_wiki;
        println!("{rank:<6} {svd_ppl:>13.2} {rilq_ppl:>16.2}");
        svd_ppls.push(svd_ppl);
        rilq_ppls.push(rilq_ppl);
    }
    let spread = |v: &[f64]| v.iter().cloned().fold(f64::MIN, f64::max)
        - v.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "\nPPL spread across ranks — SVD: {:.2}, RILQ: {:.2}  (rank-insensitivity = small spread)",
        spread(&svd_ppls),
        spread(&rilq_ppls)
    );
    Ok(())
}
