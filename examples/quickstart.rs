//! Quickstart: the RILQ pipeline in ~40 lines of public API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Pretrains (or loads the cached) tiny teacher, 2-bit quantizes it,
//! applies RILQ compensation, and prints before/after quality.

use rilq::experiments::pipeline::Lab;
use rilq::lqec::AdapterSet;
use rilq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. the runtime loads AOT artifacts (HLO text) onto the CPU PJRT client
    let rt = Runtime::new("artifacts")?;
    let mut lab = Lab::new(&rt);
    lab.pretrain_steps_override = Some(200);
    lab.calib.max_steps = 60;

    // 2. a pretrained fp teacher (cached under runs/)
    let (dims, teacher, _) = lab.teacher("tiny")?;
    println!("teacher: {} (~{:.2}M params)", dims.name, dims.params_count() as f64 / 1e6);

    // 3. quantize every linear to 2-bit RTN
    let student = lab.quantize(&dims, &teacher, "rtn", 2)?;

    // 4. evaluate the damage
    let rank = 4;
    let zeros = AdapterSet::zeros(&dims, rank);
    let fp = lab.evaluate(&lab.teacher_scorer(&dims, &teacher)?, &dims)?;
    let q = lab.evaluate(&lab.student_scorer(&dims, &teacher, &student, &zeros)?, &dims)?;

    // 5. RILQ: tune rank-4 adapters against Model-Loss + GT-Loss
    let init = lab.default_adapters(&dims, rank);
    let (adapters, res) = lab.compensate(&dims, &teacher, &student, &init, "model_gt", "rtn2")?;
    let rq = lab.evaluate(&lab.student_scorer(&dims, &teacher, &student, &adapters)?, &dims)?;

    println!("                      CSQA-avg   Wiki2-PPL");
    println!("fp16 teacher           {:>6.2}%   {:>8.2}", fp.avg_acc * 100.0, fp.ppl_wiki);
    println!("W2 quantized           {:>6.2}%   {:>8.2}", q.avg_acc * 100.0, q.ppl_wiki);
    println!("W2 + RILQ (rank {rank})     {:>6.2}%   {:>8.2}", rq.avg_acc * 100.0, rq.ppl_wiki);
    println!("({} calibration steps, {:.1}s)", res.steps, res.wall_secs);
    Ok(())
}
