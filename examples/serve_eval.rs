//! Serving-path demo: the W2A16 packed inference pipeline.
//!
//! Quantizes a model to 2-bit, RILQ-compensates, *merges* adapters QA-LoRA
//! style into per-group zero points, bit-packs the weights, and serves a
//! batched evaluation workload through the fused Pallas dequant kernel —
//! reporting throughput and the memory footprint vs fp16.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_eval [-- --fast]
//! ```

use std::time::Instant;

use rilq::eval::Scorer;
use rilq::experiments::pipeline::{fp16_bytes, quantized_model_bytes, Lab};
use rilq::lqec::{AdapterSet, GroupedAdapterSet};
use rilq::runtime::bindings::Bindings;
use rilq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    let mut lab = Lab::new(&rt);
    if std::env::args().any(|a| a == "--fast") {
        lab.pretrain_steps_override = Some(150);
        lab.calib.max_steps = 40;
    }
    let config = "tiny";
    let (dims, teacher, _) = lab.teacher(config)?;
    let rank = *rt.manifest.ranks[config].iter().min().unwrap();

    // quantize + RILQ + QA-LoRA merge => adapter-free packed weights
    let student = lab.quantize(&dims, &teacher, "rtn", 2)?;
    let init = lab.default_adapters(&dims, rank);
    let (adapters, _) = lab.compensate(&dims, &teacher, &student, &init, "model_gt", "rtn2")?;
    let grouped = GroupedAdapterSet::project(&dims, &adapters);
    let mut merged = student.clone();
    for fam in 0..7 {
        for l in 0..dims.n_layers {
            if let rilq::quant::QuantResult::Scalar(q) = &mut merged.q[fam][l] {
                grouped.merge_into(fam, l, q);
            }
        }
    }

    println!(
        "model bytes: fp16 {:.2} MiB -> packed W2 {:.2} MiB ({:.1}x smaller)",
        fp16_bytes(&dims) as f64 / (1 << 20) as f64,
        quantized_model_bytes(&dims, &merged) as f64 / (1 << 20) as f64,
        fp16_bytes(&dims) as f64 / quantized_model_bytes(&dims, &merged) as f64
    );

    // pack for the fused Pallas serving artifact
    let pname = format!("student_fwd_packed_{config}_r{rank}_w2");
    let pspec = rt.manifest.artifact(&pname)?.clone();
    let mut packed = Vec::new();
    let mut scales = Vec::new();
    let mut zeros = Vec::new();
    let mut codebook = Vec::new();
    for fam in 0..7 {
        let (mut fp, mut fs, mut fz) = (Vec::new(), Vec::new(), Vec::new());
        for l in 0..dims.n_layers {
            let q = merged.q[fam][l].as_scalar().expect("scalar quantizer");
            fp.push(q.pack());
            fs.extend_from_slice(q.scales.data());
            fz.extend_from_slice(q.zeros.data());
            codebook = q.codebook.clone();
        }
        packed.push(fp);
        scales.push(fs);
        zeros.push(fz);
    }
    let zero_ad = AdapterSet::zeros(&dims, rank); // adapters merged away
    let mut base = Bindings::new();
    base.teacher(&teacher)
        .packed(&packed, &scales, &zeros, &codebook)
        .adapters("ad.", &zero_ad.to_flat());
    rt.load(&pname)?;

    // serve a batched eval workload
    let seqs = lab.eval_seqs(&dims, rilq::data::Profile::WikiSim, 32);
    let t0 = Instant::now();
    let mut total_nll = 0.0f64;
    let mut n_tok = 0usize;
    let mut requests = 0usize;
    for chunk in seqs.chunks(dims.batch) {
        let mut batch: Vec<Vec<u32>> = chunk.to_vec();
        while batch.len() < dims.batch {
            batch.push(vec![0; dims.seq]);
        }
        let mut b = Bindings::new();
        b.copy_from(&base).tokens(&batch, &dims);
        let outs = rt.run(&pname, &b.to_literals(&pspec)?)?;
        let logp = rilq::runtime::bindings::output_f32(&pspec, &outs, "logp")?;
        for i in 0..chunk.len() {
            let per = dims.seq - 1;
            total_nll -= logp[i * per..(i + 1) * per].iter().map(|&x| x as f64).sum::<f64>();
            n_tok += per;
        }
        requests += chunk.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {requests} requests ({n_tok} scored tokens) in {wall:.2}s \
         -> {:.0} tokens/s, PPL {:.2} (adapter-free packed inference)",
        n_tok as f64 / wall,
        (total_nll / n_tok as f64).exp()
    );

    // cross-check against the merged dense reference
    let dense = rilq::model::forward::effective_weights(&merged, None);
    let native = rilq::eval::NativeScorer { dims: dims.clone(), teacher, dense: Some(dense) };
    let ppl_native = rilq::eval::perplexity(&native, &seqs)?;
    println!("native merged-dense reference PPL {ppl_native:.2} (parity check)");

    // the same reference served through the request-lifecycle engine:
    // the scoring workload runs as Request::Score traffic and shares the
    // scheduler with a sampled generation (typed Engine API demo)
    use rilq::engine::{Engine, EngineConfig, SamplingParams};
    let prompt: Vec<u32> = seqs[0][..8.min(seqs[0].len())].to_vec();
    let max_new = (dims.seq - prompt.len()).min(16);
    let engine = Engine::start(native, EngineConfig::default());
    let client = engine.client();
    let ppl_engine = rilq::eval::perplexity_client(&client, &seqs)?;
    let gen = client
        .generate(
            prompt,
            SamplingParams {
                max_new,
                temperature: 0.8,
                top_k: 16,
                top_p: 0.95,
                seed: Some(1),
                stop: Vec::new(),
            },
        )?
        .wait()?;
    let summary = engine.shutdown();
    anyhow::ensure!(
        (ppl_engine - ppl_native).abs() < 1e-6,
        "engine-served PPL diverged from the direct eval"
    );
    println!(
        "engine-served PPL {ppl_engine:.2} (== direct), plus {} sampled tokens; {summary}",
        gen.tokens.len()
    );
    Ok(())
}
