//! End-to-end validation driver (deliverable (b)/(e2e)): pretrain on the
//! synthetic corpus with the loss curve logged, 2-bit quantize, compensate
//! with Weight-SVD vs RILQ, and report the headline recovery — the same
//! code path as `rilq experiment e2e`, runnable standalone.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use rilq::experiments::e2e;
use rilq::experiments::pipeline::Lab;
use rilq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    let mut lab = Lab::new(&rt);
    if std::env::args().any(|a| a == "--fast") {
        lab.pretrain_steps_override = Some(150);
        lab.calib.max_steps = 40;
    }
    let tables = e2e::run(&mut lab)?;
    for t in &tables {
        println!("{}", t.to_markdown());
        t.save("reports", "e2e_example")?;
    }
    Ok(())
}
