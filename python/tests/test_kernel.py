"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes / ranks / group sizes / bit widths; every case
asserts allclose against ref.py. This is the core kernel signal the Rust
side depends on (the packed serving path and the custom_vjp training path
both route through these kernels).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lora_qmm import (
    lora_mm,
    lora_mm_pallas,
    lora_qmm_packed,
    vmem_footprint_bytes,
)

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# dense kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 48),
    d_in=st.integers(1, 96),
    d_out=st.integers(1, 96),
    r=st.integers(1, 16),
)
def test_lora_mm_matches_ref(t, d_in, d_out, r):
    x = rand(1, t, d_in)
    q = rand(2, d_in, d_out)
    a = rand(3, d_in, r, scale=0.1)
    bt = rand(4, r, d_out, scale=0.1)
    got = lora_mm_pallas(x, q, a, bt)
    want = ref.lora_mm_ref(x, q, a, bt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(tile=st.sampled_from([8, 16, 64, 256]))
def test_lora_mm_tiling_invariant(tile):
    """Output must be identical regardless of the output-stripe width."""
    x = rand(5, 16, 64)
    q = rand(6, 64, 64)
    a = rand(7, 64, 8, scale=0.1)
    bt = rand(8, 8, 64, scale=0.1)
    got = lora_mm_pallas(x, q, a, bt, tile_n=tile)
    want = lora_mm_pallas(x, q, a, bt, tile_n=256)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_lora_mm_custom_vjp_matches_ref_grads():
    x = rand(11, 12, 32)
    q = rand(12, 32, 24)
    a = rand(13, 32, 4, scale=0.1)
    bt = rand(14, 4, 24, scale=0.1)

    def loss_pallas(x, a, bt):
        return jnp.sum(lora_mm(x, q, a, bt) ** 2)

    def loss_ref(x, a, bt):
        return jnp.sum(ref.lora_mm_ref(x, q, a, bt) ** 2)

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, a, bt)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, a, bt)
    for p, r_ in zip(g1, g2):
        np.testing.assert_allclose(p, r_, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4]),
    cols=st.integers(1, 24),
    rows4=st.integers(1, 16),
)
def test_pack_unpack_roundtrip(bits, cols, rows4, ):
    mult = {2: 4, 3: 1, 4: 2}[bits]
    d_in = mult * rows4
    codes = jax.random.randint(
        jax.random.PRNGKey(bits * 100 + cols), (d_in, cols), 0, 2 ** bits
    )
    packed = ref.pack_codes(codes, bits)
    got = ref.unpack_codes(packed, bits)
    assert bool(jnp.all(got == codes))


def test_pack_bit_layout_pinned():
    """Byte layout pinned to match rust/src/quant/packing.rs."""
    packed = ref.pack_codes(jnp.array([[1], [2], [3], [0]]), 2)
    assert int(packed[0, 0]) == 0b0011_1001
    packed = ref.pack_codes(jnp.array([[0xA], [0x5]]), 4)
    assert int(packed[0, 0]) == 0x5A


# ---------------------------------------------------------------------------
# packed kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    bits=st.sampled_from([2, 4]),
    groups=st.integers(1, 4),
    d_out=st.integers(4, 48),
    r=st.integers(1, 8),
    t=st.integers(1, 16),
)
def test_lora_qmm_packed_matches_ref(bits, groups, d_out, r, t):
    gs = 16
    d_in = groups * gs
    key = jax.random.PRNGKey(bits * 1000 + d_out)
    codes = jax.random.randint(key, (d_in, d_out), 0, 2 ** bits)
    packed = ref.pack_codes(codes, bits)
    cb = jnp.linspace(-1.0, 1.0, 2 ** bits)
    sc = jnp.abs(rand(21, groups, d_out)) + 0.1
    z = rand(22, groups, d_out, scale=0.05)
    x = rand(23, t, d_in)
    a = rand(24, d_in, r, scale=0.1)
    bt = rand(25, r, d_out, scale=0.1)
    got = lora_qmm_packed(x, packed, sc, z, cb, a, bt, bits=bits, group_size=gs)
    want = ref.lora_qmm_packed_ref(x, packed, sc, z, cb, a, bt, bits=bits, group_size=gs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_packed_zero_adapter_is_pure_dequant_matmul():
    gs, d_in, d_out = 8, 32, 16
    codes = jax.random.randint(jax.random.PRNGKey(0), (d_in, d_out), 0, 4)
    packed = ref.pack_codes(codes, 2)
    cb = jnp.array([0.0, 1.0, 2.0, 3.0])
    sc = jnp.ones((d_in // gs, d_out))
    z = jnp.zeros((d_in // gs, d_out))
    x = rand(31, 4, d_in)
    a = jnp.zeros((d_in, 2))
    bt = jnp.zeros((2, d_out))
    got = lora_qmm_packed(x, packed, sc, z, cb, a, bt, bits=2, group_size=gs)
    want = x @ codes.astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_vmem_footprint_estimate_sane():
    # base-config shapes: one grid step must fit VMEM-scale budgets
    b = vmem_footprint_bytes(768, 384, 1024, 16, bits=2, group_size=64, tile_n=256)
    assert b < 8 << 20, f"{b} bytes"
    # packed Q stripe is 4x smaller than f32 would be
    b2 = vmem_footprint_bytes(768, 384, 1024, 16, bits=4, group_size=64, tile_n=256)
    assert b2 > b
