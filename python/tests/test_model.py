"""Layer-2 correctness: model shapes, loss scopes, training steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T
from compile.configs import TINY as cfg

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    params = M.init_teacher(cfg, KEY)
    qweights = {
        k: params[k] + 0.05 * jax.random.normal(jax.random.PRNGKey(i), params[k].shape)
        for i, k in enumerate(M.LINEARS)
    }
    adapters = M.init_adapters(cfg, 4, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (cfg.batch, cfg.seq), 0, cfg.vocab)
    return params, qweights, adapters, tokens


def test_teacher_forward_shapes(setup):
    params, _, _, tokens = setup
    out = M.teacher_forward(cfg, params, tokens)
    assert out["logits"].shape == (cfg.batch, cfg.seq, cfg.vocab)
    assert out["hidden"].shape == (cfg.batch, cfg.seq, cfg.d_model)
    assert out["layer_out"].shape == (cfg.n_layers, cfg.batch, cfg.seq, cfg.d_model)
    assert out["mid"].shape == (cfg.n_layers, cfg.batch, cfg.seq, cfg.d_ff)


def test_student_zero_adapters_match_qweights_model(setup):
    params, qweights, adapters, tokens = setup
    zero_ad = {k: jnp.zeros_like(v) for k, v in adapters.items()}
    out_s = M.student_forward(cfg, params, qweights, zero_ad, tokens)
    # manual: teacher_forward with quantized weights substituted
    params_q = dict(params)
    params_q.update(qweights)
    out_t = M.teacher_forward(cfg, params_q, tokens)
    np.testing.assert_allclose(out_s["logits"], out_t["logits"], rtol=1e-4, atol=1e-4)


def test_student_equals_teacher_when_unquantized(setup):
    params, _, adapters, tokens = setup
    zero_ad = {k: jnp.zeros_like(v) for k, v in adapters.items()}
    qweights = {k: params[k] for k in M.LINEARS}
    out_s = M.student_forward(cfg, params, qweights, zero_ad, tokens)
    out_t = M.teacher_forward(cfg, params, tokens)
    np.testing.assert_allclose(out_s["logits"], out_t["logits"], rtol=1e-4, atol=1e-4)


def test_token_logp_normalized(setup):
    params, _, _, tokens = setup
    out = M.teacher_forward(cfg, params, tokens)
    lp = M.token_logp(out["logits"], tokens)
    assert lp.shape == (cfg.batch, cfg.seq - 1)
    assert bool(jnp.all(lp < 0))


def test_causality(setup):
    params, _, _, tokens = setup
    out1 = M.teacher_forward(cfg, params, tokens)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % cfg.vocab)
    out2 = M.teacher_forward(cfg, params, tokens2)
    np.testing.assert_allclose(
        out1["logits"][:, :-1], out2["logits"][:, :-1], rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("scope", ["linear", "layer", "model", "gt", "model_gt", "model_logit"])
def test_scope_losses_finite_and_positive(setup, scope):
    params, qweights, adapters, tokens = setup
    loss, aux = M.scope_loss(cfg, scope, params, qweights, adapters, tokens)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert np.isfinite(float(aux["model_loss"]))
    assert np.isfinite(float(aux["gt_loss"]))


def test_scope_loss_zero_for_identical_student(setup):
    params, _, adapters, tokens = setup
    zero_ad = {k: jnp.zeros_like(v) for k, v in adapters.items()}
    qweights = {k: params[k] for k in M.LINEARS}
    for scope in ["linear", "layer", "model"]:
        loss, _ = M.scope_loss(cfg, scope, params, qweights, zero_ad, tokens)
        assert float(loss) < 1e-6, f"{scope}: {float(loss)}"


def test_probe_outputs(setup):
    params, qweights, adapters, tokens = setup
    layer_rel, head_rel, nll_t, nll_s = M.probe(cfg, params, qweights, adapters, tokens)
    assert layer_rel.shape == (cfg.n_layers,)
    assert float(head_rel) > 0
    assert float(nll_t) > 0 and float(nll_s) > 0


def test_compensation_step_reduces_loss(setup):
    params, qweights, adapters, tokens = setup
    step = jax.jit(T.compensation_step(cfg, "model"))
    ad = adapters
    m = {k: jnp.zeros_like(v) for k, v in ad.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in ad.items()}
    losses = []
    for t in range(6):
        ad, m, v, loss, _, _ = step(params, qweights, ad, m, v, float(t + 1), 3e-3, tokens)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_pretrain_step_reduces_loss():
    params = M.init_teacher(cfg, jax.random.PRNGKey(5))
    tokens = jax.random.randint(jax.random.PRNGKey(6), (cfg.batch, cfg.seq), 0, cfg.vocab)
    step = jax.jit(T.pretrain_step(cfg))
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in params.items()}
    losses = []
    for t in range(6):
        params, m, v, loss = step(params, m, v, float(t + 1), 1e-3, tokens)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_adam_update_moves_params_toward_gradient():
    p = {"w": jnp.array([1.0, -1.0])}
    g = {"w": jnp.array([1.0, -2.0])}
    m = {"w": jnp.zeros(2)}
    v = {"w": jnp.zeros(2)}
    p2, m2, v2 = T.adam_update(p, g, m, v, 1.0, 0.1)
    # Adam's first step is approximately -lr * sign(g)
    assert float(p2["w"][0]) < 1.0
    assert float(p2["w"][1]) > -1.0
    assert float(m2["w"][0]) > 0
    assert float(v2["w"][0]) > 0


def test_packed_forward_matches_dense(setup):
    from compile.kernels import ref as kref

    params, _, adapters, tokens = setup
    gs = cfg.group_size
    packed, scales, zeros = {}, {}, {}
    qdense = {}
    cb = jnp.array([0.0, 1.0, 2.0, 3.0]) / 3.0 * 2 - 1.0  # arbitrary 2-bit codebook
    for i, name in enumerate(M.LINEARS):
        di, do = M.linear_dims(cfg, name)
        key = jax.random.PRNGKey(100 + i)
        codes = jax.random.randint(key, (cfg.n_layers, di, do), 0, 4)
        sc = jnp.abs(jax.random.normal(key, (cfg.n_layers, di // gs, do))) * 0.05 + 0.01
        z = jnp.zeros((cfg.n_layers, di // gs, do))
        packed[name] = jnp.stack([kref.pack_codes(codes[l], 2) for l in range(cfg.n_layers)])
        scales[name] = sc
        zeros[name] = z
        qdense[name] = jnp.stack(
            [kref.dequant(codes[l], sc[l], z[l], cb, gs) for l in range(cfg.n_layers)]
        )
    out_p = M.student_forward_packed(
        cfg, params, packed, scales, zeros, cb, adapters, tokens, bits=2
    )
    out_d = M.student_forward(cfg, params, qdense, adapters, tokens)
    np.testing.assert_allclose(out_p["logits"], out_d["logits"], rtol=1e-3, atol=1e-3)
