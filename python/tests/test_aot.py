"""AOT lowering sanity: artifact construction, HLO text hygiene, manifest
consistency. Uses the tiny config only (fast)."""

import json
import os

import jax
import pytest

from compile import aot
from compile.configs import CONFIGS, RANKS, SCOPE_SETS

jax.config.update("jax_platform_name", "cpu")


def entries():
    return aot.build_artifacts(CONFIGS["tiny"])


def test_expected_artifact_kinds_present():
    kinds = {e["meta"]["kind"] for e in entries()}
    assert kinds == {
        "pretrain_step",
        "teacher_fwd",
        "student_fwd",
        "probe",
        "train_step",
        "student_fwd_packed",
    }


def test_train_step_grid_covers_config():
    names = {e["name"] for e in entries()}
    for rank in RANKS["tiny"]:
        for scope in SCOPE_SETS["tiny"]:
            assert f"train_step_tiny_r{rank}_{scope}" in names


def test_lowered_hlo_has_no_elided_constants(tmp_path):
    # the bug that cost us an afternoon: default printing elides large
    # constants as `{...}` and the Rust-side parser zero-fills them
    e = next(x for x in entries() if x["name"] == "teacher_fwd_tiny")
    rec = aot.lower_entry(e, str(tmp_path), force=True)
    text = open(tmp_path / rec["file"]).read()
    assert "{...}" not in text
    assert "ENTRY" in text
    # new-style metadata attrs break the xla_extension 0.5.1 parser
    assert "source_end_line" not in text


def test_manifest_records_match_specs(tmp_path):
    e = next(x for x in entries() if x["meta"]["kind"] == "train_step")
    rec = aot.lower_entry(e, str(tmp_path), force=True)
    assert len(rec["inputs"]) == len(e["in_specs"])
    assert len(rec["outputs"]) == len(e["out_names"])
    # tokens arg typed int32 with the config's batch geometry
    tok = next(i for i in rec["inputs"] if i["name"] == "tokens")
    assert tok["dtype"] == "int32"
    assert tok["shape"] == [CONFIGS["tiny"].batch, CONFIGS["tiny"].seq]


def test_existing_manifest_is_consistent():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    m = json.load(open(path))
    assert m["version"] == 1
    names = {a["name"] for a in m["artifacts"]}
    for cfg_name in m["configs"]:
        assert f"teacher_fwd_{cfg_name}" in names
        assert f"pretrain_step_{cfg_name}" in names
    for a in m["artifacts"]:
        f = os.path.join(os.path.dirname(path), a["file"])
        assert os.path.exists(f), a["file"]
