"""Layer-2: LLaMA-style decoder in JAX — fp teacher + quantized student with
LoRA adapters — plus every discrepancy-loss scope the paper studies.

Architecture (matches the paper's Fig. 2(a) structure, scaled down):
  embed -> N x [ RMSNorm -> MHA(RoPE, causal) -> res
                 RMSNorm -> SwiGLU FFN        -> res ] -> RMSNorm -> LM head

Quantized linears (7 per layer): wq wk wv wo (attention) and wg wu wd
(SwiGLU gate/up/down — the paper's W_FFN1/W_FFN2 family). Embedding, norms
and LM head stay full-precision, as in all the paper's quantizer baselines.

Every student linear goes through the Layer-1 Pallas kernel
(`kernels.lora_qmm.lora_mm`, custom_vjp) so the lowered HLO artifacts
exercise the fused dequant+matmul+LoRA path end to end.

Parameter layout: per-layer weights are *stacked* along a leading [L, ...]
axis and the decoder runs as `lax.scan` over layers — this keeps the HLO
compact and gives the Rust side a fixed, manifest-described argument list.
Weights use the x @ W convention, i.e. shape [d_in, d_out].
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .configs import ModelConfig
from .kernels.lora_qmm import lora_mm, lora_qmm_packed

# The seven quantized linear-module families, in canonical order. This order
# defines artifact argument order; rust/src/runtime/artifact.rs relies on it
# via manifest.json.
LINEARS = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")

TEACHER_KEYS = ("embed", "wq", "wk", "wv", "wo", "wg", "wu", "wd",
                "ln1", "ln2", "fnorm", "head")

EPS = 1e-6


# ---------------------------------------------------------------------------
# shapes / init
# ---------------------------------------------------------------------------

def linear_dims(cfg: ModelConfig, name: str):
    """(d_in, d_out) of each linear family."""
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "wg": (d, f), "wu": (d, f), "wd": (f, d),
    }[name]


def teacher_shapes(cfg: ModelConfig):
    d, f, v, l = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    shapes = {"embed": (v, d)}
    for name in LINEARS:
        di, do = linear_dims(cfg, name)
        shapes[name] = (l, di, do)
    shapes["ln1"] = (l, d)
    shapes["ln2"] = (l, d)
    shapes["fnorm"] = (d,)
    shapes["head"] = (d, v)
    return shapes


def adapter_shapes(cfg: ModelConfig, rank: int):
    """Ordered dict of LoRA adapter shapes: for each linear family,
    `{name}.a` [L, d_in, r] and `{name}.b` [L, d_out, r]."""
    l = cfg.n_layers
    shapes = {}
    for name in LINEARS:
        di, do = linear_dims(cfg, name)
        shapes[f"{name}.a"] = (l, di, rank)
        shapes[f"{name}.b"] = (l, do, rank)
    return shapes


def qweight_shapes(cfg: ModelConfig):
    l = cfg.n_layers
    return {name: (l,) + linear_dims(cfg, name) for name in LINEARS}


def init_teacher(cfg: ModelConfig, key):
    """He-style init for the fp teacher (pretrained in-repo by the Rust
    coordinator running the pretrain_step artifact)."""
    shapes = teacher_shapes(cfg)
    params = {}
    for name, shape in shapes.items():
        key, sub = jax.random.split(key)
        if name in ("ln1", "ln2", "fnorm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            std = (2.0 / fan_in) ** 0.5 * 0.5
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def init_adapters(cfg: ModelConfig, rank: int, key, scale: float = 0.01):
    """Default LoRA init: A gaussian, B zeros (so A·Bᵀ = 0 at step 0)."""
    shapes = adapter_shapes(cfg, rank)
    out = {}
    for name, shape in shapes.items():
        if name.endswith(".a"):
            key, sub = jax.random.split(key)
            out[name] = scale * jax.random.normal(sub, shape, jnp.float32)
        else:
            out[name] = jnp.zeros(shape, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, g):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * lax.rsqrt(ms + EPS) * g


def rope_tables(cfg: ModelConfig):
    hd = cfg.head_dim
    pos = jnp.arange(cfg.seq, dtype=jnp.float32)[:, None]
    freq = 10000.0 ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)[None, :]
    ang = pos * freq                      # [S, hd/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, H, S, hd]; rotate pairs (even, odd)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x1 * sin + x2 * cos
    out = jnp.stack([xr1, xr2], axis=-1)
    return out.reshape(x.shape)


def attention(q, k, v, cfg: ModelConfig, cos, sin):
    """q/k/v: [B, S, d] -> [B, S, d], causal, RoPE."""
    b, s, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split(x):
        return x.reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    qh = apply_rope(qh, cos, sin)
    kh = apply_rope(kh, cos, sin)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _teacher_layer(cfg, cos, sin, h, wl):
    """One decoder layer with fp weights; returns (h_out, captures).
    captures = (x_attn_in, attn_cat, x_ffn_in, ffn_mid, h_out) — the inputs
    each linear family sees, needed by the Linear-Loss scope."""
    x1 = rmsnorm(h, wl["ln1"])
    q = x1 @ wl["wq"]
    k = x1 @ wl["wk"]
    v = x1 @ wl["wv"]
    att = attention(q, k, v, cfg, cos, sin)
    h = h + att @ wl["wo"]
    x2 = rmsnorm(h, wl["ln2"])
    g = jax.nn.silu(x2 @ wl["wg"])
    u = x2 @ wl["wu"]
    mid = g * u
    h = h + mid @ wl["wd"]
    return h, (x1, att, x2, mid, h)


def teacher_forward(cfg: ModelConfig, params, tokens):
    """Returns dict with per-layer captures, final hidden, logits, nll."""
    cos, sin = rope_tables(cfg)
    h = params["embed"][tokens]           # [B, S, d]

    def step(h, per_layer):
        h, cap = _teacher_layer(cfg, cos, sin, h, per_layer)
        return h, cap

    per_layer = {k: params[k] for k in LINEARS + ("ln1", "ln2")}
    h, caps = lax.scan(step, h, per_layer)
    hidden = rmsnorm(h, params["fnorm"])
    logits = hidden @ params["head"]
    return {
        "x_attn": caps[0],    # [L, B, S, d]  input to wq/wk/wv
        "att": caps[1],       # [L, B, S, d]  input to wo
        "x_ffn": caps[2],     # [L, B, S, d]  input to wg/wu
        "mid": caps[3],       # [L, B, S, f]  input to wd
        "layer_out": caps[4], # [L, B, S, d]  residual stream after layer
        "hidden": hidden,
        "logits": logits,
    }


def _student_linear(x, q, a, b):
    """x: [B, S, d_in] through the Pallas fused kernel; b is [d_out, r]."""
    bsz, s, di = x.shape
    y = lora_mm(x.reshape(bsz * s, di), q, a, b.T)
    return y.reshape(bsz, s, -1)


def _student_layer(cfg, cos, sin, h, wl):
    x1 = rmsnorm(h, wl["ln1"])
    q = _student_linear(x1, wl["wq"], wl["wq.a"], wl["wq.b"])
    k = _student_linear(x1, wl["wk"], wl["wk.a"], wl["wk.b"])
    v = _student_linear(x1, wl["wv"], wl["wv.a"], wl["wv.b"])
    att = attention(q, k, v, cfg, cos, sin)
    h = h + _student_linear(att, wl["wo"], wl["wo.a"], wl["wo.b"])
    x2 = rmsnorm(h, wl["ln2"])
    g = jax.nn.silu(_student_linear(x2, wl["wg"], wl["wg.a"], wl["wg.b"]))
    u = _student_linear(x2, wl["wu"], wl["wu.a"], wl["wu.b"])
    mid = g * u
    h = h + _student_linear(mid, wl["wd"], wl["wd.a"], wl["wd.b"])
    return h, h


def student_forward(cfg: ModelConfig, params, qweights, adapters, tokens):
    """Student = frozen fp embed/norms/head + quantized linears + LoRA.
    Returns dict(layer_out [L,B,S,d], hidden, logits)."""
    cos, sin = rope_tables(cfg)
    h = params["embed"][tokens]
    per_layer = {k: qweights[k] for k in LINEARS}
    per_layer.update({k: adapters[k] for k in adapters})
    per_layer["ln1"] = params["ln1"]
    per_layer["ln2"] = params["ln2"]

    def step(h, wl):
        return _student_layer(cfg, cos, sin, h, wl)

    h, layer_out = lax.scan(step, h, per_layer)
    hidden = rmsnorm(h, params["fnorm"])
    logits = hidden @ params["head"]
    return {"layer_out": layer_out, "hidden": hidden, "logits": logits}


# ---------------------------------------------------------------------------
# metrics / losses
# ---------------------------------------------------------------------------

def token_logp(logits, tokens):
    """Log-prob of the realized next token: [B, S-1]."""
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nxt = tokens[:, 1:]
    return jnp.take_along_axis(logp, nxt[..., None], axis=-1)[..., 0]


def nll_loss(logits, tokens):
    return -jnp.mean(token_logp(logits, tokens))


def rel_fro2(y, t):
    """Relative squared Frobenius discrepancy ‖y−t‖²/‖t‖² (scale-stable
    across scopes; the paper's raw ‖·‖_F objective differs only by a
    per-scope constant factor for a fixed calibration set)."""
    return jnp.sum((y - t) ** 2) / (jnp.sum(t ** 2) + EPS)


def rel_err(y, t):
    """Paper's rank-sensitivity metric E = |(Y − Y^q)/Y| (aggregated as
    mean |Δ| / mean |Y| for numerical stability)."""
    return jnp.mean(jnp.abs(y - t)) / (jnp.mean(jnp.abs(t)) + EPS)


def linear_scope_loss(cfg, params, qweights, adapters, caps):
    """Eq. 3: per-linear discrepancy with the *teacher's* input X."""
    inputs = {"wq": caps["x_attn"], "wk": caps["x_attn"], "wv": caps["x_attn"],
              "wo": caps["att"], "wg": caps["x_ffn"], "wu": caps["x_ffn"],
              "wd": caps["mid"]}

    def per_family(name):
        x = inputs[name]                       # [L, B, S, d_in]
        w = params[name]                       # [L, d_in, d_out]
        q = qweights[name]
        a = adapters[f"{name}.a"]
        b = adapters[f"{name}.b"]

        def one(x_l, w_l, q_l, a_l, b_l):
            t = x_l @ w_l
            bsz, s, di = x_l.shape
            y = lora_mm(x_l.reshape(bsz * s, di), q_l, a_l, b_l.T)
            return rel_fro2(y.reshape(t.shape), t)

        return jnp.mean(jax.vmap(one)(x, w, q, a, b))

    return sum(per_family(n) for n in LINEARS) / len(LINEARS)


def layer_scope_loss(student_out, caps):
    """Eq. 4: per-decoder-layer discrepancy, student stream propagated."""
    y = student_out["layer_out"]   # [L, B, S, d]
    t = caps["layer_out"]
    per = jax.vmap(rel_fro2)(y, t)
    return jnp.mean(per)


def model_scope_loss(student_out, caps, target: str = "hidden"):
    """Eq. 5 (RILQ's Model-Loss): discrepancy at the final decoder output
    (`hidden`) or at the logits (Table 11 variant)."""
    return rel_fro2(student_out[target], caps[target])


def scope_loss(cfg, scope, params, qweights, adapters, tokens):
    """Build the scalar loss for a scope; returns (loss, aux_dict)."""
    caps = teacher_forward(cfg, params, tokens)
    caps = jax.tree_util.tree_map(lax.stop_gradient, caps)
    out = student_forward(cfg, params, qweights, adapters, tokens)
    gt = nll_loss(out["logits"], tokens)
    model_l = model_scope_loss(out, caps, "hidden")
    if scope == "linear":
        loss = linear_scope_loss(cfg, params, qweights, adapters, caps)
    elif scope == "layer":
        loss = layer_scope_loss(out, caps)
    elif scope == "model":
        loss = model_l
    elif scope == "model_logit":
        loss = model_scope_loss(out, caps, "logits")
    elif scope == "gt":
        loss = gt
    elif scope == "model_gt":          # RILQ: equal weighting (paper: 0.5/0.5)
        loss = 0.5 * model_l + 0.5 * gt
    else:
        raise ValueError(f"unknown scope {scope}")
    return loss, {"model_loss": model_l, "gt_loss": gt}


# ---------------------------------------------------------------------------
# probes (Fig. 4a/4b) and packed serving forward
# ---------------------------------------------------------------------------

def probe(cfg: ModelConfig, params, qweights, adapters, tokens):
    """Returns (layer_rel [L], head_rel, nll_teacher, nll_student)."""
    caps = teacher_forward(cfg, params, tokens)
    out = student_forward(cfg, params, qweights, adapters, tokens)
    layer_rel = jax.vmap(rel_err)(out["layer_out"], caps["layer_out"])
    head_rel = rel_err(out["logits"], caps["logits"])
    return (layer_rel, head_rel,
            nll_loss(caps["logits"], tokens), nll_loss(out["logits"], tokens))


def _student_linear_packed(x, pq, sc, z, cb, a, b, bits, group_size):
    bsz, s, di = x.shape
    y = lora_qmm_packed(x.reshape(bsz * s, di), pq, sc, z, cb, a, b.T,
                        bits=bits, group_size=group_size)
    return y.reshape(bsz, s, -1)


def student_forward_packed(cfg: ModelConfig, params, packed, scales, zeros,
                           codebook, adapters, tokens, *, bits: int):
    """Serving-path forward: weights stay bit-packed in 'HBM'; each linear
    runs the fused Pallas dequant kernel. packed/scales/zeros are dicts over
    LINEARS with leading [L, ...]."""
    cos, sin = rope_tables(cfg)
    gs = cfg.group_size
    h = params["embed"][tokens]
    lin = functools.partial(_student_linear_packed, bits=bits, group_size=gs)

    per_layer = {}
    for n in LINEARS:
        per_layer[f"{n}.pq"] = packed[n]
        per_layer[f"{n}.sc"] = scales[n]
        per_layer[f"{n}.z"] = zeros[n]
        per_layer[f"{n}.a"] = adapters[f"{n}.a"]
        per_layer[f"{n}.b"] = adapters[f"{n}.b"]
    per_layer["ln1"] = params["ln1"]
    per_layer["ln2"] = params["ln2"]

    def at(wl, n):
        return (wl[f"{n}.pq"], wl[f"{n}.sc"], wl[f"{n}.z"], codebook,
                wl[f"{n}.a"], wl[f"{n}.b"])

    def step(h, wl):
        x1 = rmsnorm(h, wl["ln1"])
        q = lin(x1, *at(wl, "wq"))
        k = lin(x1, *at(wl, "wk"))
        v = lin(x1, *at(wl, "wv"))
        att = attention(q, k, v, cfg, cos, sin)
        h = h + lin(att, *at(wl, "wo"))
        x2 = rmsnorm(h, wl["ln2"])
        g = jax.nn.silu(lin(x2, *at(wl, "wg")))
        u = lin(x2, *at(wl, "wu"))
        h = h + lin(g * u, *at(wl, "wd"))
        return h, None

    h, _ = lax.scan(step, h, per_layer)
    hidden = rmsnorm(h, params["fnorm"])
    logits = hidden @ params["head"]
    return {"hidden": hidden, "logits": logits}
