"""Model configurations shared by the JAX model, the AOT lowering, and
(through artifacts/manifest.json) the Rust coordinator.

HLO shapes are static, so every (config, rank, scope) combination that the
Rust side wants to run must be lowered here at `make artifacts` time.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    seq: int
    batch: int          # batch baked into train/eval artifacts
    group_size: int     # quantization group size along d_in

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def params_count(self) -> int:
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        return v * d + l * (4 * d * d + 3 * d * f + 2 * d) + d + d * v

    def to_dict(self):
        return asdict(self)


TINY = ModelConfig("tiny", d_model=64, n_layers=2, n_heads=2, d_ff=192,
                   vocab=256, seq=64, batch=8, group_size=32)
SMALL = ModelConfig("small", d_model=192, n_layers=4, n_heads=4, d_ff=512,
                    vocab=512, seq=128, batch=8, group_size=64)
BASE = ModelConfig("base", d_model=384, n_layers=6, n_heads=6, d_ff=1024,
                   vocab=1024, seq=192, batch=4, group_size=64)

CONFIGS = {c.name: c for c in (TINY, SMALL, BASE)}

# Loss scopes lowered as training artifacts. `model_logit` is the Table 11
# variant that applies Model-Loss at the logits instead of the final
# decoder-layer activation.
SCOPES = ("linear", "layer", "model", "gt", "model_gt", "model_logit")

# Adapter ranks baked per config. The paper sweeps 16..256 on 4096-dim
# LLaMA; our d_model is 10-20x smaller so the rank grid scales down to keep
# rank/d_model ratios comparable.
RANKS = {
    "tiny": (4, 8),
    "small": (4, 8, 16, 32, 64),
    "base": (8, 16),
}

# Scopes lowered per config (the full grid only for `small`, which carries
# the main experiments).
SCOPE_SETS = {
    "tiny": ("model_gt", "model"),
    "small": SCOPES,
    "base": ("model_gt",),
}
