"""AOT lowering: every (config, rank, scope) variant of the L2 graphs is
lowered ONCE here to HLO *text* plus a manifest describing the flat
argument/result lists. After `make artifacts` the Rust binary is fully
self-contained — Python never runs on the request path.

HLO text (not `.serialize()`d protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the `xla` crate binds) rejects; the text parser reassigns ids.

Usage:  cd python && python -m compile.aot --out ../artifacts \
            [--configs tiny,small,base] [--force]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .configs import CONFIGS, RANKS, SCOPE_SETS, ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: default HLO printing elides large constants as `{...}`,
    # which the text parser on the Rust side silently zero-fills (we lost a
    # debugging afternoon to RoPE tables becoming zeros). Print them fully.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # ... and new-style metadata (source_end_line etc.) breaks the 0.5.1
    # parser; the default as_hlo_text() happens to omit both features.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO still contains elided constants"
    return text


# ---------------------------------------------------------------------------
# flat-signature plumbing
# ---------------------------------------------------------------------------

def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def dict_specs(shapes, dtype=jnp.float32):
    """Ordered [(name, ShapeDtypeStruct)] from an ordered shape dict."""
    return [(k, spec(v, dtype)) for k, v in shapes.items()]


def _entry(name, specs_in, names_out, specs_out, fn, meta):
    return {
        "name": name,
        "fn": fn,
        "in_names": [n for n, _ in specs_in],
        "in_specs": [s for _, s in specs_in],
        "out_names": names_out,
        "out_specs": specs_out,
        "meta": meta,
    }


def build_artifacts(cfg: ModelConfig):
    """Yield artifact build entries for one config."""
    d, f, v, l = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    b, s = cfg.batch, cfg.seq
    tshapes = M.teacher_shapes(cfg)
    qshapes = M.qweight_shapes(cfg)
    tok = ("tokens", spec((b, s), jnp.int32))
    scalar_t = ("t", spec((), jnp.float32))
    scalar_lr = ("lr", spec((), jnp.float32))

    t_in = dict_specs(tshapes)
    q_in = [(f"q.{k}", sp) for k, sp in dict_specs(qshapes)]
    # The student forwards never read the fp linear weights; XLA prunes
    # unused entry parameters during the HLO-text conversion, so the
    # artifact signature must list only what the graph actually uses.
    NONQUANT = ("embed", "ln1", "ln2", "fnorm", "head")
    t5_in = [(k, sp) for k, sp in t_in if k in NONQUANT]

    def unflat_teacher(args):
        return dict(zip(tshapes.keys(), args))

    def unflat_teacher5(args):
        return dict(zip(NONQUANT, args))

    def unflat_q(args):
        return dict(zip(qshapes.keys(), args))

    entries = []

    # ---- pretrain step ----------------------------------------------------
    nt = len(tshapes)
    pstep = T.pretrain_step(cfg)

    def pretrain_flat(*args):
        p = unflat_teacher(args[0:nt])
        m = unflat_teacher(args[nt:2 * nt])
        v_ = unflat_teacher(args[2 * nt:3 * nt])
        t, lr, tokens = args[3 * nt:3 * nt + 3]
        p2, m2, v2, loss = pstep(p, m, v_, t, lr, tokens)
        outs = [p2[k] for k in tshapes] + [m2[k] for k in tshapes] \
            + [v2[k] for k in tshapes] + [loss]
        return tuple(outs)

    pre_in = (t_in
              + [(f"m.{k}", sp) for k, sp in dict_specs(tshapes)]
              + [(f"v.{k}", sp) for k, sp in dict_specs(tshapes)]
              + [scalar_t, scalar_lr, tok])
    pre_out_names = ([f"p.{k}" for k in tshapes] + [f"m.{k}" for k in tshapes]
                     + [f"v.{k}" for k in tshapes] + ["loss"])
    entries.append(_entry(
        f"pretrain_step_{cfg.name}", pre_in, pre_out_names, None,
        pretrain_flat, {"kind": "pretrain_step", "config": cfg.name}))

    # ---- teacher forward --------------------------------------------------
    def teacher_flat(*args):
        p = unflat_teacher(args[0:nt])
        tokens = args[nt]
        out = M.teacher_forward(cfg, p, tokens)
        logp = M.token_logp(out["logits"], tokens)
        return logp, out["logits"], out["hidden"]

    entries.append(_entry(
        f"teacher_fwd_{cfg.name}", t_in + [tok],
        ["logp", "logits", "hidden"], None, teacher_flat,
        {"kind": "teacher_fwd", "config": cfg.name}))

    for rank in RANKS[cfg.name]:
        ashapes = M.adapter_shapes(cfg, rank)
        na = len(ashapes)
        a_in = [(f"ad.{k}", sp) for k, sp in dict_specs(ashapes)]

        def unflat_a(args, _as=ashapes):
            return dict(zip(_as.keys(), args))

        # ---- student forward (dense Q) ------------------------------------
        def student_flat(*args, _ua=unflat_a):
            p = unflat_teacher5(args[0:5])
            qw = unflat_q(args[5:5 + 7])
            ad = _ua(args[5 + 7:5 + 7 + na])
            tokens = args[5 + 7 + na]
            out = M.student_forward(cfg, p, qw, ad, tokens)
            logp = M.token_logp(out["logits"], tokens)
            return logp, out["logits"], out["hidden"]

        entries.append(_entry(
            f"student_fwd_{cfg.name}_r{rank}",
            t5_in + q_in + a_in + [tok],
            ["logp", "logits", "hidden"], None, student_flat,
            {"kind": "student_fwd", "config": cfg.name, "rank": rank}))

        # ---- probe (Fig 4a/4b metrics) -------------------------------------
        def probe_flat(*args, _ua=unflat_a):
            p = unflat_teacher(args[0:nt])
            qw = unflat_q(args[nt:nt + 7])
            ad = _ua(args[nt + 7:nt + 7 + na])
            tokens = args[nt + 7 + na]
            lr_, hr, nt_, ns = M.probe(cfg, p, qw, ad, tokens)
            return lr_, hr, nt_, ns

        entries.append(_entry(
            f"probe_{cfg.name}_r{rank}",
            t_in + q_in + a_in + [tok],
            ["layer_rel", "head_rel", "nll_teacher", "nll_student"],
            None, probe_flat,
            {"kind": "probe", "config": cfg.name, "rank": rank}))

        # ---- compensation train steps --------------------------------------
        for scope in SCOPE_SETS[cfg.name]:
            cstep = T.compensation_step(cfg, scope)

            def train_flat(*args, _ua=unflat_a, _cs=cstep):
                p = unflat_teacher(args[0:nt])
                qw = unflat_q(args[nt:nt + 7])
                base = nt + 7
                ad = _ua(args[base:base + na])
                m = _ua(args[base + na:base + 2 * na])
                v_ = _ua(args[base + 2 * na:base + 3 * na])
                t, lr, tokens = args[base + 3 * na:base + 3 * na + 3]
                ad2, m2, v2, loss, ml, gl = _cs(p, qw, ad, m, v_, t, lr, tokens)
                outs = ([ad2[k] for k in ad] + [m2[k] for k in ad]
                        + [v2[k] for k in ad] + [loss, ml, gl])
                return tuple(outs)

            tr_in = (t_in + q_in + a_in
                     + [(f"m.{k}", sp) for k, sp in dict_specs(ashapes)]
                     + [(f"v.{k}", sp) for k, sp in dict_specs(ashapes)]
                     + [scalar_t, scalar_lr, tok])
            tr_out = ([f"ad.{k}" for k in ashapes]
                      + [f"m.{k}" for k in ashapes]
                      + [f"v.{k}" for k in ashapes]
                      + ["loss", "model_loss", "gt_loss"])
            entries.append(_entry(
                f"train_step_{cfg.name}_r{rank}_{scope}",
                tr_in, tr_out, None, train_flat,
                {"kind": "train_step", "config": cfg.name, "rank": rank,
                 "scope": scope}))

    # ---- packed serving forward (W2, smallest "deploy" rank) ---------------
    for bits in (2, 4):
        rank = min(RANKS[cfg.name]) if cfg.name != "small" else 16
        ashapes = M.adapter_shapes(cfg, rank)
        na = len(ashapes)
        a_in = [(f"ad.{k}", sp) for k, sp in dict_specs(ashapes)]
        gs = cfg.group_size
        pq_in, sc_in, z_in = [], [], []
        for nme in M.LINEARS:
            di, do = M.linear_dims(cfg, nme)
            prows = di * bits // 8
            pq_in.append((f"pq.{nme}", spec((l, prows, do), jnp.uint8)))
            sc_in.append((f"sc.{nme}", spec((l, di // gs, do))))
            z_in.append((f"z.{nme}", spec((l, di // gs, do))))
        cb_in = [("codebook", spec((2 ** bits,)))]

        def packed_flat(*args, _na=na, _ash=ashapes, _bits=bits):
            p = unflat_teacher5(args[0:5])
            i = 5
            pq = dict(zip(M.LINEARS, args[i:i + 7])); i += 7
            sc = dict(zip(M.LINEARS, args[i:i + 7])); i += 7
            z = dict(zip(M.LINEARS, args[i:i + 7])); i += 7
            cb = args[i]; i += 1
            ad = dict(zip(_ash.keys(), args[i:i + _na])); i += _na
            tokens = args[i]
            out = M.student_forward_packed(cfg, p, pq, sc, z, cb, ad, tokens,
                                           bits=_bits)
            logp = M.token_logp(out["logits"], tokens)
            return logp, out["logits"]

        entries.append(_entry(
            f"student_fwd_packed_{cfg.name}_r{rank}_w{bits}",
            t5_in + pq_in + sc_in + z_in + cb_in + a_in + [tok],
            ["logp", "logits"], None, packed_flat,
            {"kind": "student_fwd_packed", "config": cfg.name, "rank": rank,
             "bits": bits}))

    return entries


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _spec_json(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_entry(entry, out_dir, force=False):
    name = entry["name"]
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    fn = jax.jit(entry["fn"])
    lowered = fn.lower(*entry["in_specs"])
    out_specs = [jax.ShapeDtypeStruct(o.shape, o.dtype)
                 for o in jax.tree_util.tree_leaves(lowered.out_info)]
    if force or not os.path.exists(path):
        text = to_hlo_text(lowered)
        with open(path, "w") as fp:
            fp.write(text)
    record = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "meta": entry["meta"],
        "inputs": [{"name": n, **_spec_json(s)}
                   for n, s in zip(entry["in_names"], entry["in_specs"])],
        "outputs": [{"name": n, **_spec_json(s)}
                    for n, s in zip(entry["out_names"], out_specs)],
    }
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,base")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    records = []
    for cname in args.configs.split(","):
        cfg = CONFIGS[cname]
        for entry in build_artifacts(cfg):
            rec = lower_entry(entry, args.out, force=args.force)
            records.append(rec)
            print(f"  lowered {rec['name']}  "
                  f"({len(rec['inputs'])} in / {len(rec['outputs'])} out)",
                  flush=True)

    manifest = {
        "version": 1,
        "configs": {c: CONFIGS[c].to_dict() for c in args.configs.split(",")},
        "ranks": {c: list(RANKS[c]) for c in args.configs.split(",")},
        "scopes": {c: list(SCOPE_SETS[c]) for c in args.configs.split(",")},
        "artifacts": records,
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as fp:
        json.dump(manifest, fp, indent=1)
    print(f"wrote {mpath} ({len(records)} artifacts)")


if __name__ == "__main__":
    main()
