"""Train-step graph builders: Adam fused into the HLO so the Rust
coordinator's calibration loop is a single PJRT execute per step.

Two step families:
  * compensation_step — the paper's LQEC optimization: gradients w.r.t. the
    LoRA adapters only (teacher + quantized weights frozen), loss given by
    one of the six scopes in model.scope_loss.
  * pretrain_step — full-parameter causal-LM training of the fp teacher
    (the repo pretrains its own base models; repro band = 0 means no
    external checkpoints).

Adam is implemented inline (no optax in the image): step count `t` and
learning rate `lr` are *inputs*, so the Rust driver owns the schedule and
early stopping without needing new artifacts.
"""

import jax
import jax.numpy as jnp

from . import model as M
from .configs import ModelConfig

B1, B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adam_update(params, grads, m, v, t, lr):
    """One Adam step over arbitrary pytrees. `t` is the 1-based step."""
    def upd(p, g, m_, v_):
        m2 = B1 * m_ + (1 - B1) * g
        v2 = B2 * v_ + (1 - B2) * g * g
        mhat = m2 / (1 - B1 ** t)
        vhat = v2 / (1 - B2 ** t)
        return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, new_m, new_v


def compensation_step(cfg: ModelConfig, scope: str):
    """Returns step(params, qweights, adapters, m, v, t, lr, tokens) ->
    (adapters', m', v', loss, model_loss, gt_loss)."""

    def step(params, qweights, adapters, m, v, t, lr, tokens):
        def loss_fn(ad):
            return M.scope_loss(cfg, scope, params, qweights, ad, tokens)

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(adapters)
        adapters2, m2, v2 = adam_update(adapters, grads, m, v, t, lr)
        return adapters2, m2, v2, loss, aux["model_loss"], aux["gt_loss"]

    return step


def pretrain_step(cfg: ModelConfig):
    """Returns step(params, m, v, t, lr, tokens) -> (params', m', v', loss)."""

    def step(params, m, v, t, lr, tokens):
        def loss_fn(p):
            out = M.teacher_forward(cfg, p, tokens)
            return M.nll_loss(out["logits"], tokens)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, m2, v2 = adam_update(params, grads, m, v, t, lr)
        return params2, m2, v2, loss

    return step
