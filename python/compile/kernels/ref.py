"""Pure-jnp oracle for the Layer-1 Pallas kernels.

Everything in this file is straight-line jax.numpy with no Pallas, so it
serves three roles:

1. the correctness reference that `python/tests/test_kernel.py` sweeps the
   Pallas kernels against (hypothesis-driven shape/dtype sweeps);
2. the building block for the *training* graphs (Pallas has no autodiff;
   the custom_vjp backward in lora_qmm.py reuses these functions);
3. executable documentation of the packing / group-dequant conventions that
   the Rust side (`rust/src/quant/packing.rs`) must match bit-for-bit.

Packing convention (must stay in sync with Rust):
  * codes are quantization indices in [0, 2^bits)
  * 2-bit: 4 codes per byte, code i of a byte at bit position 2*i
    (little-endian within the byte), packed along the d_in axis
  * 4-bit: 2 codes per byte, code i at bit position 4*i
  * groups of `group_size` consecutive d_in rows share one (scale, zero)
  * dequant:  w[i, j] = zero[g, j] + scale[g, j] * codebook[code[i, j]],
    where g = i // group_size
"""

import jax.numpy as jnp


def pack_codes(codes, bits: int):
    """Pack integer codes [d_in, d_out] along axis 0. Returns uint8 array
    [d_in * bits / 8, d_out] for bits in {2, 4}; 3-bit stays unpacked
    (one code per byte) because cross-byte straddling isn't worth it at
    simulation scale."""
    codes = codes.astype(jnp.uint8)
    d_in, d_out = codes.shape
    if bits == 2:
        assert d_in % 4 == 0
        c = codes.reshape(d_in // 4, 4, d_out)
        return (c[:, 0] | (c[:, 1] << 2) | (c[:, 2] << 4) | (c[:, 3] << 6)).astype(jnp.uint8)
    if bits == 4:
        assert d_in % 2 == 0
        c = codes.reshape(d_in // 2, 2, d_out)
        return (c[:, 0] | (c[:, 1] << 4)).astype(jnp.uint8)
    if bits == 3:
        return codes
    raise ValueError(f"unsupported bits={bits}")


def unpack_codes(packed, bits: int):
    """Inverse of pack_codes; returns int32 codes [d_in, d_out]."""
    if bits == 2:
        parts = [(packed >> s) & 0x3 for s in (0, 2, 4, 6)]
        stacked = jnp.stack(parts, axis=1)  # [d_in//4, 4, d_out]
        return stacked.reshape(-1, packed.shape[1]).astype(jnp.int32)
    if bits == 4:
        parts = [(packed >> s) & 0xF for s in (0, 4)]
        stacked = jnp.stack(parts, axis=1)
        return stacked.reshape(-1, packed.shape[1]).astype(jnp.int32)
    if bits == 3:
        return packed.astype(jnp.int32)
    raise ValueError(f"unsupported bits={bits}")


def dequant(codes, scales, zeros, codebook, group_size: int):
    """Group-wise dequantization.

    codes:    [d_in, d_out] int
    scales:   [d_in / group_size, d_out] f32
    zeros:    [d_in / group_size, d_out] f32
    codebook: [2^bits] f32 (e.g. [0,1,2,3] for uniform 2-bit, NF2 values
              for NormalFloat)
    returns   [d_in, d_out] f32
    """
    vals = codebook[codes]  # gather
    s = jnp.repeat(scales, group_size, axis=0)
    z = jnp.repeat(zeros, group_size, axis=0)
    return z + s * vals


def lora_mm_ref(x, q, a, bt):
    """Dense-Q reference: y = x @ q + (x @ a) @ bt.

    x: [t, d_in], q: [d_in, d_out], a: [d_in, r], bt: [r, d_out].
    """
    return x @ q + (x @ a) @ bt


def lora_qmm_packed_ref(x, packed, scales, zeros, codebook, a, bt,
                        bits: int, group_size: int):
    """Packed-Q reference: dequantize then lora_mm_ref."""
    codes = unpack_codes(packed, bits)
    w = dequant(codes, scales, zeros, codebook, group_size)
    return lora_mm_ref(x, w, a, bt)
