"""Layer-1 Pallas kernels: fused (dequant +) matmul + LoRA correction.

Two kernels, both computing   y = x · W_eff,  W_eff = deq(Q) + A·Bᵀ :

* `lora_mm`        — dense-f32 Q. Used inside every L2 forward (teacher-free
                     student path), wrapped in a custom_vjp so the *training*
                     graphs can differentiate through it (Pallas has no
                     autodiff rule; the backward reuses the jnp oracle, which
                     tests prove numerically identical).
* `lora_qmm_packed`— bit-packed uint8 Q with group-wise (scale, zero) and a
                     scalar codebook, dequantized tile-by-tile inside the
                     kernel. This is the W2A16 serving path: HBM traffic is
                     the packed footprint (2 bits/weight + group metadata).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid partitions the
output dimension into `tile_n`-wide stripes; each grid step pulls one packed
Q stripe + its group metadata into VMEM, dequantizes in-register, feeds the
MXU with an [t, d_in]×[d_in, tile_n] matmul, and adds the rank-r correction
as a second tiny MXU matmul — A·Bᵀ is never materialized. On CPU we run
`interpret=True` (Mosaic custom-calls cannot execute on the CPU PJRT
plugin), so these lower into the same HLO artifact the Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default output-stripe width. For the simulated model sizes a whole matrix
# fits comfortably in VMEM-scale blocks, so tiles only kick in for the
# larger configs; the TPU-perf estimate in DESIGN.md assumes 128-wide
# stripes at LLaMA-scale d_out.
DEFAULT_TILE_N = 256


def _pick_tile(d_out: int, tile_n: int) -> int:
    if d_out <= tile_n:
        return d_out
    # largest divisor of d_out that is <= tile_n keeps BlockSpecs exact
    for t in range(tile_n, 0, -1):
        if d_out % t == 0:
            return t
    return d_out


# ---------------------------------------------------------------------------
# dense-Q kernel
# ---------------------------------------------------------------------------

def _lora_mm_kernel(x_ref, q_ref, a_ref, bt_ref, y_ref):
    x = x_ref[...]
    # main matmul on the (future) MXU; fp32 accumulation
    acc = jnp.dot(x, q_ref[...], preferred_element_type=jnp.float32)
    # rank-r correction: (x @ A) @ Bᵀ — two skinny matmuls, never A·Bᵀ
    acc += jnp.dot(jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32),
                   bt_ref[...], preferred_element_type=jnp.float32)
    y_ref[...] = acc


def lora_mm_pallas(x, q, a, bt, tile_n: int = DEFAULT_TILE_N):
    """y = x @ q + (x @ a) @ bt via Pallas (interpret mode)."""
    t, d_in = x.shape
    d_out = q.shape[1]
    r = a.shape[1]
    tn = _pick_tile(d_out, tile_n)
    grid = (d_out // tn,)
    return pl.pallas_call(
        _lora_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, d_in), lambda j: (0, 0)),
            pl.BlockSpec((d_in, tn), lambda j: (0, j)),
            pl.BlockSpec((d_in, r), lambda j: (0, 0)),
            pl.BlockSpec((r, tn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((t, tn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((t, d_out), jnp.float32),
        interpret=True,
    )(x, q, a, bt)


@jax.custom_vjp
def lora_mm(x, q, a, bt):
    """Differentiable fused LoRA matmul: Pallas forward, jnp backward."""
    return lora_mm_pallas(x, q, a, bt)


def _lora_mm_fwd(x, q, a, bt):
    return lora_mm_pallas(x, q, a, bt), (x, q, a, bt)


def _lora_mm_bwd(resids, dy):
    x, q, a, bt = resids
    # dx = dy @ (q + a bt)ᵀ = dy @ qᵀ + (dy @ btᵀ) @ aᵀ
    dx = dy @ q.T + (dy @ bt.T) @ a.T
    # q is frozen in every caller; a zero cotangent lets XLA DCE the node.
    dq = jnp.zeros_like(q)
    da = x.T @ (dy @ bt.T)
    dbt = (x @ a).T @ dy
    return dx, dq, da, dbt


lora_mm.defvjp(_lora_mm_fwd, _lora_mm_bwd)


# ---------------------------------------------------------------------------
# packed-Q kernel (serving path)
# ---------------------------------------------------------------------------

def _lora_qmm_packed_kernel(x_ref, pq_ref, s_ref, z_ref, cb_ref, a_ref,
                            bt_ref, y_ref, *, bits: int, group_size: int):
    x = x_ref[...]
    packed = pq_ref[...]
    # in-register unpack: shift/mask lanes then interleave along d_in
    if bits == 2:
        parts = [(packed >> s) & 0x3 for s in (0, 2, 4, 6)]
        codes = jnp.stack(parts, axis=1).reshape(-1, packed.shape[1])
    elif bits == 4:
        parts = [(packed >> s) & 0xF for s in (0, 4)]
        codes = jnp.stack(parts, axis=1).reshape(-1, packed.shape[1])
    elif bits == 3:
        codes = packed
    else:
        raise ValueError(f"bits={bits}")
    codes = codes.astype(jnp.int32)
    vals = cb_ref[...][codes]  # scalar-codebook gather
    s = jnp.repeat(s_ref[...], group_size, axis=0)
    z = jnp.repeat(z_ref[...], group_size, axis=0)
    w = z + s * vals
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc += jnp.dot(jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32),
                   bt_ref[...], preferred_element_type=jnp.float32)
    y_ref[...] = acc


def lora_qmm_packed(x, packed, scales, zeros, codebook, a, bt, *,
                    bits: int, group_size: int,
                    tile_n: int = DEFAULT_TILE_N):
    """Fused packed-dequant + matmul + LoRA. Inference-only (no vjp)."""
    t, d_in = x.shape
    d_out = packed.shape[1]
    r = a.shape[1]
    packed_rows = packed.shape[0]
    n_groups = scales.shape[0]
    assert n_groups * group_size == d_in, "group metadata mismatch"
    tn = _pick_tile(d_out, tile_n)
    grid = (d_out // tn,)
    ncodes = codebook.shape[0]
    kern = functools.partial(_lora_qmm_packed_kernel, bits=bits,
                             group_size=group_size)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, d_in), lambda j: (0, 0)),
            pl.BlockSpec((packed_rows, tn), lambda j: (0, j)),
            pl.BlockSpec((n_groups, tn), lambda j: (0, j)),
            pl.BlockSpec((n_groups, tn), lambda j: (0, j)),
            pl.BlockSpec((ncodes,), lambda j: (0,)),
            pl.BlockSpec((d_in, r), lambda j: (0, 0)),
            pl.BlockSpec((r, tn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((t, tn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((t, d_out), jnp.float32),
        interpret=True,
    )(x, packed, scales, zeros, codebook, a, bt)


def vmem_footprint_bytes(t, d_in, d_out, r, *, bits, group_size,
                         tile_n=DEFAULT_TILE_N):
    """Static VMEM-footprint estimate for one grid step of the packed
    kernel — the quantity the §Perf analysis tracks (interpret-mode
    wallclock is not a TPU proxy)."""
    tn = _pick_tile(d_out, tile_n)
    n_groups = d_in // group_size
    x_b = t * d_in * 4
    pq_b = (d_in * bits // 8 if bits in (2, 4) else d_in) * tn
    meta_b = 2 * n_groups * tn * 4
    deq_b = d_in * tn * 4  # dequantized stripe held for the MXU
    ab_b = (d_in * r + r * tn) * 4
    y_b = t * tn * 4
    return x_b + pq_b + meta_b + deq_b + ab_b + y_b
